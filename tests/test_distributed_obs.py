"""Distributed observability — trace propagation, exemplars, SLOs, and
the persisted trace store.

Pins: W3C traceparent parsing (malformed never fails a request); one
trace id across transport spans, the engine ``QueryTrace``, and every
per-shard sub-trace of a sharded execution, proven through one HTTP
request; coalesced followers linking ``coalesced_into`` the leader;
cache hits linking ``produced_by`` the populating run; exemplar-linked
histograms and ``# HELP`` metadata in the Prometheus exposition; the SLO
engine's verdicts / error budgets / multi-window burn-rate alerts with
an injected clock; the trace store's bounded ring, tail-based sampling,
and the bit-identity of mining the persisted trace log with Algorithm 1;
and ``GET /readyz`` degrading to 503 with reasons."""

import asyncio
import json
import os
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import dfg_numpy
from repro.data import ProcessSpec, generate_memmap_log, generate_repository
from repro.graph import partition_memmap_log
from repro.obs import (
    MetricsRegistry,
    Objective,
    SLOEngine,
    TraceStore,
    mint_context,
    parse_traceparent,
)
from repro.obs.context import TraceContext
from repro.query import Q, QueryEngine, QueryPlanError
from repro.serve import QueryService
from repro.transport import (
    TransportApp,
    TransportConfig,
    TransportServer,
    reassemble_ndjson,
)

EVENTS = 6_000


def run(coro):
    return asyncio.run(coro)


@pytest.fixture()
def repo():
    return generate_repository(300, ProcessSpec(seed=11), seed=11)


@pytest.fixture()
def sharded(tmp_path):
    base = generate_memmap_log(
        str(tmp_path / "log"), EVENTS,
        ProcessSpec(num_activities=10, seed=5, horizon_days=30), seed=5,
    )
    return partition_memmap_log(base, 3, str(tmp_path / "k3"))


def make_app(service, tmp_path=None, **cfg):
    cfg.setdefault("hot_cutoff_s", 0.05)
    if tmp_path is not None:
        cfg.setdefault("trace_dir", str(tmp_path / "traces"))
    return TransportApp(service, TransportConfig(**cfg))


# -- trace context ------------------------------------------------------------

def test_traceparent_round_trip():
    ctx = mint_context()
    back = parse_traceparent(ctx.to_traceparent())
    assert back == ctx
    assert len(ctx.trace_id) == 32 and len(ctx.span_id) == 16
    unsampled = TraceContext(ctx.trace_id, ctx.span_id, False)
    assert unsampled.to_traceparent().endswith("-00")
    assert parse_traceparent(unsampled.to_traceparent()).sampled is False


@pytest.mark.parametrize("header", [
    "",
    "garbage",
    "00-short-beef-01",
    "00-" + "g" * 32 + "-" + "a" * 16 + "-01",       # non-hex
    "00-" + "A" * 32 + "-" + "a" * 16 + "-01",       # uppercase
    "00-" + "0" * 32 + "-" + "a" * 16 + "-01",       # all-zero trace id
    "00-" + "a" * 32 + "-" + "0" * 16 + "-01",       # all-zero span id
    "ff-" + "a" * 32 + "-" + "b" * 16 + "-01",       # forbidden version
    "00-" + "a" * 32 + "-" + "b" * 16,               # missing flags
])
def test_malformed_traceparent_is_rejected(header):
    assert parse_traceparent(header) is None


def test_malformed_traceparent_never_fails_the_request(repo, tmp_path):
    svc = QueryService()
    svc.register("bpi", repo)
    app = make_app(svc, tmp_path)
    resp = run(app.handle(
        {"log": "bpi", "sink": "dfg"}, traceparent="not-a-traceparent"
    ))
    app.close()
    assert resp.status == 200
    assert len(resp.headers["X-Trace-Id"]) == 32  # fresh root, not an error


# -- end-to-end propagation over HTTP -----------------------------------------

def _http(method, url, body=None, headers=None):
    data = json.dumps(body).encode() if body is not None else None
    req = urllib.request.Request(
        url, data=data, method=method, headers=headers or {}
    )
    try:
        with urllib.request.urlopen(req, timeout=30) as f:
            return f.status, dict(f.headers), f.read()
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), e.read()


def test_one_trace_id_across_transport_engine_and_shards(sharded, tmp_path):
    """The acceptance path: one HTTP request with an inbound traceparent
    over a sharded log — the response echoes the trace id, the engine
    trace and every shard sub-trace carry it, and the persisted store
    holds the stitched request tree."""
    svc = QueryService()
    svc.register("sharded", sharded)
    app = make_app(svc, tmp_path)
    inbound = mint_context()
    req = {
        "log": "sharded", "sink": "dfg",
        "backend": "sharded-graph", "trace": True,
    }

    async def go():
        srv = TransportServer(app)
        await srv.start()
        loop = asyncio.get_running_loop()

        def exercise():
            out = {}
            out["query"] = _http(
                "POST", srv.address + "/query", req,
                headers={"traceparent": inbound.to_traceparent()},
            )
            out["stream"] = _http(
                "POST", srv.address + "/query/stream", req,
                headers={"traceparent": inbound.to_traceparent()},
            )
            return out

        out = await loop.run_in_executor(None, exercise)
        await srv.stop()
        return out

    out = run(go())
    status, headers, body = out["query"]
    assert status == 200
    tid = inbound.trace_id
    # the transport adopted the caller's trace and echoed it back
    assert headers["X-Trace-Id"] == tid
    echoed = parse_traceparent(headers["traceparent"])
    assert echoed.trace_id == tid and echoed.span_id != inbound.span_id
    payload = json.loads(body)
    assert payload["trace_id"] == tid
    # the engine trace and every per-shard sub-trace share the id
    tr = payload["trace"]
    assert tr["trace_id"] == tid
    branches = tr["branches"]
    assert len(branches) == 3
    for b in branches:
        assert b["trace"]["trace_id"] == tid
        assert b["trace"]["parent_span_id"] == tr["span_id"]

    # NDJSON streaming carries the same id on the meta line
    status, headers, body = out["stream"]
    assert status == 200
    assert headers["X-Trace-Id"] == tid
    streamed = reassemble_ndjson(body.decode().splitlines())
    assert streamed["trace_id"] == tid

    # the persisted store holds the stitched tree: the transport record
    # parents the engine record, shard spans nested under it
    recs = app.trace_store.find(tid)
    t_recs = [r for r in recs if r["source"] == "transport"]
    eng_recs = [r for r in recs if r["source"] != "transport"]
    assert len(t_recs) == 2 and eng_recs  # /query and /query/stream
    t_spans = {r["span_id"] for r in t_recs}
    assert all(r["parent_span_id"] in t_spans for r in eng_recs)
    span_names = [s["name"] for s in t_recs[0]["spans"]]
    assert "probe" in span_names and "admit" in span_names
    assert any(n.startswith("queue_wait:") for n in span_names)
    assert "execute" in span_names
    app.close()


def test_coalesced_follower_links_leader(sharded, tmp_path):
    class Gated(QueryService):
        def __init__(self):
            super().__init__(QueryEngine(memory_budget_events=1_000))
            self.gate = threading.Event()

        def query(self, request, trace_context=None):
            if request.get("sink") == "dfg":
                assert self.gate.wait(timeout=30), "gate timeout"
            return super().query(request, trace_context)

    svc = Gated()
    svc.register("live", sharded)
    app = make_app(svc, tmp_path)
    req = {"log": "live", "sink": "dfg"}

    async def go():
        t1 = asyncio.create_task(app.handle(req))
        await asyncio.sleep(0.05)          # leader held at the gate
        t2 = asyncio.create_task(app.handle(req))
        await asyncio.sleep(0.05)
        svc.gate.set()
        return await asyncio.gather(t1, t2)

    r1, r2 = run(go())
    leader = r1 if r1.headers["X-Coalesced"] == "0" else r2
    follower = r2 if leader is r1 else r1
    assert follower.headers["X-Coalesced"] == "1"
    ltid = leader.headers["X-Trace-Id"]
    ftid = follower.headers["X-Trace-Id"]
    assert ltid != ftid
    # the shared payload names the producing (leader) execution
    assert follower.payload["trace_id"] == ltid
    f_rec = next(
        r for r in app.trace_store.find(ftid) if r["source"] == "transport"
    )
    assert f_rec["links"]["coalesced_into"] == ltid
    assert "await_leader" in [s["name"] for s in f_rec["spans"]]
    app.close()


def test_cache_hit_links_producing_run(repo):
    engine = QueryEngine()
    miss = Q.log(repo).using(engine).dfg()
    hit = Q.log(repo).using(engine).dfg()
    assert hit.from_cache
    assert hit.trace.trace_id != miss.trace.trace_id
    assert hit.trace.links["produced_by"] == miss.trace.trace_id
    # the retained id survives service payloads too
    svc = QueryService(engine)
    svc.register("bpi", repo)
    payload = svc.query({"log": "bpi", "sink": "dfg"})
    assert len(payload["trace_id"]) == 32


# -- exemplars and HELP metadata ----------------------------------------------

def test_histogram_exemplars_and_help():
    m = MetricsRegistry()
    h = m.histogram("request_latency_seconds", "End-to-end latency", lane="hot")
    m.counter("transport_requests_total", "Requests served", lane="hot")
    h.observe(0.003, trace_id="aa" * 16)
    h.observe(0.004, trace_id="bb" * 16)   # worse in the same bucket wins
    h.observe(5.0, trace_id="cc" * 16)     # lands in the overflow bucket
    h.observe(0.0035)                      # no trace id: never an exemplar
    ex = h.exemplars()
    assert ("bb" * 16, 0.004) in ex.values()
    assert ("cc" * 16, 5.0) in ex.values()
    text = m.to_prometheus()
    assert "# HELP request_latency_seconds End-to-end latency" in text
    assert "# HELP transport_requests_total Requests served" in text
    assert f'# {{trace_id="{"bb" * 16}"}} 0.004' in text
    assert f'# {{trace_id="{"cc" * 16}"}} 5' in text
    snap = m.to_dict()["request_latency_seconds{lane=hot}"]
    assert any(e["trace_id"] == "bb" * 16 for e in snap["exemplars"])


def test_exemplars_respect_floor():
    m = MetricsRegistry()
    h = m.histogram("lat", "latency")
    for i in range(3):
        h.observe(0.01, trace_id=f"{i:032x}")
    snap = m.to_dict(floor=5)["lat"]
    assert "exemplars" not in snap  # sub-floor counts leak nothing


# -- SLO engine ---------------------------------------------------------------

def _slo_setup(observations, threshold_s=0.025, target=0.99):
    m = MetricsRegistry()
    h = m.histogram("request_latency_seconds", lane="hot")
    for x in observations:
        h.observe(x)
    clock = {"t": 1000.0}
    eng = SLOEngine(
        m,
        objectives=[Objective(
            name="warm_latency", kind="latency", target=target,
            metric="request_latency_seconds", labels=(("lane", "hot"),),
            threshold_s=threshold_s,
        )],
        windows_s=(60.0, 300.0),
        now=lambda: clock["t"],
    )
    return m, h, eng, clock


def test_slo_latency_verdict_and_budget():
    _, _, eng, _ = _slo_setup([0.001] * 99 + [0.5])
    out = eng.evaluate()
    obj = out["objectives"][0]
    assert obj["ok"] is True and out["ok"] is True
    assert obj["total"] == 100
    assert obj["error_budget_remaining"] == pytest.approx(0.0, abs=0.05)
    # now degrade: p99 over threshold
    _, _, eng2, _ = _slo_setup([0.1] * 100)
    obj2 = eng2.evaluate()["objectives"][0]
    assert obj2["ok"] is False
    assert obj2["measured"] > 0.025


def test_slo_burn_rate_alert_needs_every_window():
    m, h, eng, clock = _slo_setup([0.001] * 1000)
    eng.tick()                      # healthy baseline at t=1000
    clock["t"] += 300.0
    eng.tick()                      # still healthy at t=1300
    out = eng.evaluate(tick=False)
    obj = out["objectives"][0]
    assert obj["alert"] is False and out["alerts"] == []
    # sustained burn: every subsequent event is bad, across both windows
    for _ in range(400):
        h.observe(0.2)
    clock["t"] += 60.0
    eng.tick()
    clock["t"] += 300.0
    for _ in range(400):
        h.observe(0.2)
    eng.tick()
    out = eng.evaluate(tick=False)
    obj = out["objectives"][0]
    burns = [b for b in obj["burn_rates"].values() if b is not None]
    assert burns and all(b > 14.4 for b in burns)
    assert obj["alert"] is True and out["alerts"] == ["warm_latency"]


def test_slo_availability_objective():
    m = MetricsRegistry()
    good = m.counter("transport_requests_total", lane="hot")
    bad = m.counter("transport_shed_total", reason="queue")
    eng = SLOEngine(m, objectives=[Objective(
        name="availability", kind="availability", target=0.999,
        metric="transport_requests_total",
        bad_metric="transport_shed_total",
    )])
    good.inc(2000)
    obj = eng.evaluate()["objectives"][0]
    assert obj["ok"] is True and obj["good_ratio"] == 1.0
    bad.inc(100)
    obj = eng.evaluate()["objectives"][0]
    assert obj["ok"] is False
    assert obj["error_budget_remaining"] < 0  # budget overdrawn


def test_slo_floor_hides_counts():
    _, _, eng, _ = _slo_setup([0.001] * 3)
    obj = eng.evaluate(floor=10)["objectives"][0]
    assert obj["ok"] is None and obj["total"] == 0 and obj["good"] == 0


def test_slo_sink_and_http_endpoint(repo, tmp_path):
    svc = QueryService()
    svc.register("bpi", repo)
    app = make_app(svc, tmp_path)

    async def go():
        for _ in range(3):
            await app.handle({"log": "bpi", "sink": "dfg"})
        sink = await app.handle({"sink": "slo"})
        srv = TransportServer(app)
        await srv.start()
        loop = asyncio.get_running_loop()
        http = await loop.run_in_executor(
            None, lambda: _http("GET", srv.address + "/slo")
        )
        await srv.stop()
        return sink, http

    sink, (status, _, body) = run(go())
    assert sink.status == 200
    names = {o["name"] for o in sink.payload["objectives"]}
    assert names == {"warm_latency", "availability"}
    warm = next(
        o for o in sink.payload["objectives"] if o["name"] == "warm_latency"
    )
    assert warm["total"] >= 3 and warm["ok"] is True
    assert status == 200
    assert {o["name"] for o in json.loads(body)["objectives"]} == names


# -- persisted trace store ----------------------------------------------------

def _run_traces(store, n, repo, **engine_kw):
    engine = QueryEngine(**engine_kw)
    engine.trace_store = store
    q = Q.log(repo).using(engine)
    for _ in range(n):
        q.dfg()
    return engine


def test_trace_store_ring_is_bounded(repo, tmp_path):
    store = TraceStore(
        str(tmp_path / "tr"), max_bytes=64 * 1024, segments=3
    )
    _run_traces(store, 200, repo)
    files = [f for f in os.listdir(tmp_path / "tr") if f.endswith(".jsonl")]
    assert len(files) <= 3
    total = sum(
        os.path.getsize(tmp_path / "tr" / f) for f in files
    )
    assert total <= 64 * 1024 + 8 * 1024  # ring bound (+1 in-flight line)
    assert len(store) == 200              # everything was offered and kept
    store.close()


def test_trace_store_tail_sampling(repo, tmp_path):
    store = TraceStore(str(tmp_path / "tr"), sample_every=10, slo_latency_s=0.5)
    engine = QueryEngine()
    engine.trace_store = store
    q = Q.log(repo).using(engine)
    for _ in range(20):
        q.dfg()                            # fast, healthy: decimated 1-in-10
    kept_before = len(store)
    assert kept_before == 2
    with pytest.raises(QueryPlanError):
        q.neighborhood("no-such-activity") # errors are always kept
    assert len(store) == kept_before + 1
    recs = list(store.read_records())
    assert sum(1 for r in recs if r["error"]) == 1
    store.close()


def test_unsampled_context_kept_only_by_tail_rules(repo, tmp_path):
    store = TraceStore(str(tmp_path / "tr"), sample_every=1)
    engine = QueryEngine()
    engine.trace_store = store
    ctx = TraceContext(mint_context().trace_id, "ab" * 8, sampled=False)
    with engine.trace_scope(ctx):
        Q.log(repo).using(engine).dfg()    # healthy + unsampled: dropped
    assert len(store) == 0
    with engine.trace_scope(ctx):
        with pytest.raises(QueryPlanError):
            Q.log(repo).using(engine).neighborhood("nope")
    assert len(store) == 1                 # the error overrides the flag
    store.close()


def test_trace_store_mines_bit_identical_to_algorithm1(repo, tmp_path):
    """``Q.log(store.to_repository()).dfg()`` == the numpy Algorithm 1
    oracle over the same read-back event table — the persisted trace log
    is a first-class event log."""
    store = TraceStore(str(tmp_path / "tr"))
    engine = _run_traces(store, 3, repo)
    Q.log(repo).using(engine).histogram()
    own = store.to_repository()
    assert own.num_events > 0
    res = Q.log(own).using(QueryEngine()).dfg()
    src, dst, valid = own.df_pairs()
    expect = dfg_numpy(src, dst, valid, own.num_activities)
    assert res.names == own.activity_names
    np.testing.assert_array_equal(np.asarray(res.value), expect)
    # the mined process contains the engine's execution chain
    assert "parse" in res.names
    store.close()


def test_trace_store_find_resumes_across_instances(repo, tmp_path):
    store = TraceStore(str(tmp_path / "tr"))
    engine = _run_traces(store, 2, repo)
    tid = Q.log(repo).using(engine).dfg().trace.trace_id
    store.close()
    reopened = TraceStore(str(tmp_path / "tr"))  # resumes highest segment
    assert [r["trace_id"] for r in reopened.find(tid)]
    reopened.close()


# -- readiness ----------------------------------------------------------------

def test_readyz_ok_and_degraded(repo, tmp_path):
    svc = QueryService()
    svc.register("bpi", repo)
    app = make_app(svc, tmp_path)

    async def go(a):
        srv = TransportServer(a)
        await srv.start()
        loop = asyncio.get_running_loop()
        out = await loop.run_in_executor(
            None, lambda: _http("GET", srv.address + "/readyz")
        )
        await srv.stop()
        return out

    status, _, body = run(go(app))
    report = json.loads(body)
    assert status == 200 and report["ready"] is True
    assert report["checks"]["lane_hot"]["depth"] == 0

    # a zero-capacity hot lane is permanently saturated: degraded
    svc2 = QueryService()
    svc2.register("bpi", repo)
    app2 = make_app(svc2, None, max_depth_hot=0)
    status, _, body = run(go(app2))
    report = json.loads(body)
    assert status == 503 and report["ready"] is False
    assert "lane_hot_saturated" in report["reasons"]
