"""Fixture: two sinks, one of which the planner forgets."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class DFGSink:
    backend: str = "auto"


@dataclasses.dataclass(frozen=True)
class OrphanSink:
    depth: int = 1


SINKS = (DFGSink, OrphanSink)
