"""Fixture: handles DFGSink only — OrphanSink silently falls through."""

from .ast import DFGSink


def plan(sink):
    if isinstance(sink, DFGSink):
        return "dfg"
    return "??"  # no decision about OrphanSink: the violation
