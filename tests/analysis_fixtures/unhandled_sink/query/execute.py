"""Fixture: covers every sink via the tuple alias — no violation here."""

from .ast import SINKS


def execute(sink):
    if isinstance(sink, SINKS):
        return "ok"
    raise TypeError(sink)
