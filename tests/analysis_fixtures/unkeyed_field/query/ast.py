"""Fixture: three cache-key violations — an unkeyed post-init attribute, a
non-frozen plan dataclass, and an explicit payload that forgets a field."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class WindowSink:
    t0: float
    t1: float

    def __post_init__(self):
        # not a dataclass field: invisible to asdict, never keyed
        object.__setattr__(self, "span", self.t1 - self.t0)


@dataclasses.dataclass
class MutableSink:
    k: int


@dataclasses.dataclass(frozen=True)
class ShardedDFGSink:
    backend: str = "sharded-graph"

    def bind(self, k):
        # public (non-underscore) grown attribute on a sharded plan node:
        # two plans with different shard counts would collide on one key
        object.__setattr__(self, "num_shards", k)
        return self


@dataclasses.dataclass(frozen=True)
class LogicalPlan:
    source: str
    sink: WindowSink

    def _payload(self):
        return [self.source]  # forgets self.sink

    def key(self):
        return str(self._payload())
