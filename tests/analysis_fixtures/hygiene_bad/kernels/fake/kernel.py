"""Fixture: ambient state inside a kernel body."""

import time

import numpy as np


def fake_kernel(x):
    jitter = np.random.uniform()  # VIOLATION: RNG in a kernel path
    return x + time.time() + jitter  # VIOLATION: wall clock in a kernel


def fake_seed():
    return time.perf_counter_ns()  # VIOLATION
