"""Fixture: lock-discipline violations — an unlocked mutation of inferred
protected state, an unlocked mutation of annotated state, a blocking call
under a lock, and an inverted acquisition order."""

import threading


class StatsRegistry:
    def __init__(self):
        self._lock = threading.Lock()
        self.counts = {}

    def inc(self, key):
        with self._lock:
            self.counts[key] = self.counts.get(key, 0) + 1

    def reset(self):
        self.counts = {}  # VIOLATION: protected attr mutated outside lock

    def _wipe_locked(self):
        self.counts = {}  # caller holds the lock: exempt by convention


class AnnotatedRegistry:
    """The only mutation site is the buggy one — inference alone cannot see
    it; the ``# guarded by`` annotation declares the contract."""

    def __init__(self):
        self._lock = threading.Lock()
        self.hists = {}  # guarded by _lock

    def observe(self, key, value):
        self.hists[key] = value  # VIOLATION


class BlockingUnderLock:
    def __init__(self):
        self._lock = threading.Lock()
        self.data = {}

    def load(self, path):
        with self._lock:
            with open(path) as fh:  # VIOLATION: I/O while holding the lock
                self.data = {"raw": fh.read()}


class InvertedOrder:
    def __init__(self):
        self._a_lock = threading.Lock()
        self._b_lock = threading.Lock()
        self.x = 0

    def forward(self):
        with self._a_lock:
            with self._b_lock:
                self.x = 1

    def backward(self):
        with self._b_lock:
            with self._a_lock:  # VIOLATION: opposite nesting order
                self.x = 2
