from .ast import DFGSink, HistogramSink


def plan(sink):
    if isinstance(sink, DFGSink):
        return "dfg"
    if isinstance(sink, HistogramSink):
        return "hist"
    raise TypeError(sink)
