from .ast import DFGSink, HistogramSink, ShardedDFGSink


def plan(sink):
    if isinstance(sink, DFGSink):
        return "dfg"
    if isinstance(sink, HistogramSink):
        return "hist"
    if isinstance(sink, ShardedDFGSink):
        return "sharded-graph"
    raise TypeError(sink)
