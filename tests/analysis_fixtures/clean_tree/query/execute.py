import threading

from .ast import SINKS


class Engine:
    def __init__(self):
        self._lock = threading.Lock()
        self.memo = {}  # guarded by _lock

    def run(self, sink):
        if not isinstance(sink, SINKS):
            raise TypeError(sink)
        with self._lock:
            self.memo[type(sink).__name__] = sink
        return "ok"
