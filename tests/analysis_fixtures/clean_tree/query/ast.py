"""Fixture: a miniature tree every rule must pass."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class DFGSink:
    backend: str = "auto"


@dataclasses.dataclass(frozen=True)
class HistogramSink:
    pass


SINKS = (DFGSink, HistogramSink)


@dataclasses.dataclass(frozen=True)
class LogicalPlan:
    source: str
    sink: object

    def _payload(self):
        return [self.source, dataclasses.asdict(self.sink)]
