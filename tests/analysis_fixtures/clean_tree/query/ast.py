"""Fixture: a miniature tree every rule must pass."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class DFGSink:
    backend: str = "auto"


@dataclasses.dataclass(frozen=True)
class HistogramSink:
    backend: str = "auto"


@dataclasses.dataclass(frozen=True)
class ShardedDFGSink:
    """Sharded-tier shape: a pinned backend plus a private resolution memo
    (underscore attributes are fingerprint-keyed, not payload-keyed)."""

    backend: str = "sharded-graph"

    def resolve(self):
        object.__setattr__(self, "_shard_memo", ())
        return self


SINKS = (DFGSink, HistogramSink, ShardedDFGSink)


@dataclasses.dataclass(frozen=True)
class LogicalPlan:
    source: str
    sink: object

    def _payload(self):
        return [self.source, dataclasses.asdict(self.sink)]
