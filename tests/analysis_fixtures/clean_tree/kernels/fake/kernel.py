def clean_kernel(x, scale):
    return x * scale
