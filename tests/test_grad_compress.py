"""Int8 gradient compression + error feedback."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.compat import make_mesh, shard_map
from repro.train import ErrorFeedback, compressed_psum, dequantize, quantize


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
    q, s = quantize(g)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize(q, s) - g))
    assert err.max() <= float(s) / 2 + 1e-7  # half-ULP of the int8 grid


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10**6), scale=st.floats(1e-6, 1e6))
def test_quantize_property(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(32,)) * scale, jnp.float32)
    q, s = quantize(g)
    rel = np.abs(np.asarray(dequantize(q, s) - g)) / (float(s) + 1e-30)
    assert rel.max() <= 0.5 + 1e-5


def test_compressed_psum_single_device():
    mesh = make_mesh((1,), ("pod",), devices=jax.devices()[:1])
    g = jnp.asarray(np.random.default_rng(1).normal(size=(64,)), jnp.float32)
    out = jax.jit(
        shard_map(
            lambda x: compressed_psum(x, "pod"),
            mesh=mesh,
            in_specs=jax.sharding.PartitionSpec(None),
            out_specs=jax.sharding.PartitionSpec(None),
        )
    )(g)
    # N=1 → mean == dequantized value; bounded by quantization error only
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=float(
        jnp.max(jnp.abs(g))) / 127.0)


def test_error_feedback_recovers_small_signal():
    """A gradient component far below the quantization step is lost without
    EF but accumulates and eventually transmits with EF."""
    big, small = 1.0, 1e-4  # small « big/127 (one int8 quantum ≈ 7.9e-3)
    grads = {"w": jnp.asarray([big, small], jnp.float32)}
    ef = ErrorFeedback(grads)
    n = 400
    sent = np.zeros(2)
    for _ in range(n):
        out = ef.compress(grads)
        sent += np.asarray(out["w"])
    quantum = big / 127.0
    # EF transmits the small signal in whole quanta; cumulative error is
    # bounded by one quantum, so over n rounds it tracks n·small
    assert abs(sent[1] - n * small) <= quantum + 1e-9
    assert sent[1] > 0  # without EF this is exactly 0 forever
    assert abs(sent[0] - n * big) / (n * big) < 1e-3


def test_error_feedback_convergence_quadratic():
    """SGD with int8+EF gradients still converges on a quadratic."""
    rng = np.random.default_rng(2)
    target = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    w = jnp.zeros((16,))
    ef = ErrorFeedback({"w": w})
    lr = 0.05
    for _ in range(400):
        g = {"w": 2 * (w - target)}
        cg = ef.compress(g)
        w = w - lr * cg["w"]
    assert float(jnp.sum((w - target) ** 2)) < 1e-3
