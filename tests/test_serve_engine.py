"""Batched serving engine: ragged prompts, waves, stop tokens, consistency."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serve import ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("starcoder2-3b").reduced()
    cfg = dataclasses.replace(cfg, vocab_size=64, loss_chunk=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, ServeEngine(
        cfg, params, max_batch=4, max_cache=64, q_chunk=16
    )


def test_generate_ragged_batch(engine):
    cfg, params, eng = engine
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9, 10], [11]]
    res = eng.generate(prompts, max_new_tokens=8)
    assert len(res) == 4
    for r, p in zip(sorted(res, key=lambda r: r.prompt), sorted(prompts)):
        assert r.prompt == p
    for r in res:
        assert len(r.tokens) == 8
        assert all(0 <= t < cfg.vocab_size for t in r.tokens)


def test_waves_beyond_max_batch(engine):
    cfg, params, eng = engine
    prompts = [[i + 1, i + 2] for i in range(10)]  # 10 > max_batch=4
    res = eng.generate(prompts, max_new_tokens=4)
    assert len(res) == 10


def test_ragged_equals_solo_greedy(engine):
    """Greedy decoding of a prompt must be identical whether it is served
    alone or inside a ragged batch (per-seq positions are honored)."""
    cfg, params, eng = engine
    target = [5, 9, 2, 7]
    solo = eng.generate([target], max_new_tokens=6)[0].tokens
    batched = eng.generate(
        [[1], target, [3, 3, 3, 3, 3, 3, 3]], max_new_tokens=6
    )
    got = next(r for r in batched if r.prompt == target).tokens
    assert got == solo


def test_stop_token(engine):
    cfg, params, eng = engine
    res = eng.generate([[1, 2]], max_new_tokens=30, stop_token=None)[0]
    # find which token greedy decoding emits, then stop on it
    first = res.tokens[0]
    res2 = eng.generate([[1, 2]], max_new_tokens=30, stop_token=first)[0]
    assert res2.finished == "stop"
    assert len(res2.tokens) == 0


def test_telemetry_recorded(engine):
    cfg, params, eng = engine
    eng.generate([[1, 2, 3]], max_new_tokens=3)
    acts = set()
    repo = eng.collector.to_repository()
    acts = set(repo.activity_names)
    assert "prefill" in acts and "decode" in acts


def test_mine_telemetry_through_query_engine(engine):
    """The serving engine's self-forensics DFG goes through repro.query."""
    cfg, params, eng = engine
    eng.generate([[1, 2, 3], [4, 5]], max_new_tokens=3)
    res = eng.mine_telemetry()
    assert "prefill" in res.names and "decode" in res.names
    # a healthy wave is prefill → decode → decode …: the prefill→decode
    # edge must be present and decode must self-loop
    p, d = res.names.index("prefill"), res.names.index("decode")
    assert res.value[p, d] >= 1
    assert res.value[d, d] >= 1
