"""Per-architecture smoke tests: REDUCED configs of the same family run one
forward/train step (and a prefill→decode step) on CPU; shapes + finiteness
asserted.  Full configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import (
    count_params,
    decode_step,
    init_caches,
    init_params,
    prefill,
    train_loss,
)

BATCH, SEQ = 2, 32


def _batch_for(cfg, batch=BATCH, seq=SEQ):
    rng = np.random.default_rng(0)
    b = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(batch, seq)), jnp.int32
        ),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(batch, seq)), jnp.int32
        ),
    }
    if cfg.family == "vlm":
        b["vision_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.n_patches, cfg.d_model)), jnp.float32
        )
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.enc_seq, cfg.d_model)), jnp.float32
        )
    return b


@pytest.fixture(scope="module")
def reduced_params():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name).reduced()
            cache[name] = (cfg, init_params(cfg, jax.random.PRNGKey(0)))
        return cache[name]

    return get


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch, reduced_params):
    cfg, params = reduced_params(arch)
    batch = _batch_for(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: train_loss(cfg, p, batch, q_chunk=16)
    )(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat), (
        f"{arch}: non-finite grads"
    )
    # loss near ln(V) at init (uniform predictions)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 2.0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_smoke(arch, reduced_params):
    cfg, params = reduced_params(arch)
    batch = _batch_for(cfg)
    caches, logits = prefill(cfg, params, batch, cache_len=SEQ + 4, q_chunk=16)
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    total = SEQ + (cfg.n_patches if cfg.family == "vlm" else 0)
    logits2, caches2 = decode_step(
        cfg, params, tok, caches, jnp.int32(total)
    )
    assert logits2.shape == (BATCH, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, dtype=np.float32)))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_forward(arch, reduced_params):
    """Prefill(S) then decode(token S) must equal prefill(S+1) logits —
    the KV-cache/decode path is numerically consistent with the parallel
    forward."""
    if arch == "whisper-tiny":
        pytest.skip("encdec decode uses dynamic sinusoidal pos — covered below")
    cfg, params = reduced_params(arch)
    if cfg.n_experts:
        # exact-consistency check needs drop-free routing: with finite
        # capacity, the (S+1)-token forward can drop different tokens than
        # the S-token prefill (standard Switch semantics, not a bug)
        import dataclasses as _dc

        cfg = _dc.replace(cfg, capacity_factor=float(cfg.n_experts))
    rng = np.random.default_rng(7)
    S = 24
    toks = rng.integers(0, cfg.vocab_size, size=(1, S + 1))
    b_s = {"tokens": jnp.asarray(toks[:, :S], jnp.int32)}
    b_s1 = {"tokens": jnp.asarray(toks, jnp.int32)}
    if cfg.family == "vlm":
        vis = jnp.asarray(rng.normal(size=(1, cfg.n_patches, cfg.d_model)),
                          jnp.float32)
        b_s["vision_embeds"] = vis
        b_s1["vision_embeds"] = vis
    n_prefix = cfg.n_patches if cfg.family == "vlm" else 0
    cache_len = S + 1 + n_prefix
    caches, _ = prefill(cfg, params, b_s, cache_len=cache_len, q_chunk=16,
                        cache_dtype=jnp.float32)
    last_tok = jnp.asarray(toks[:, S : S + 1], jnp.int32)
    logits_dec, _ = decode_step(
        cfg, params, last_tok, caches, jnp.int32(S + n_prefix)
    )
    _, logits_par = prefill(cfg, params, b_s1, cache_len=cache_len + 1,
                            q_chunk=16, cache_dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_par), rtol=2e-2, atol=2e-2
    )


def test_whisper_decode_consistency(reduced_params):
    cfg, params = reduced_params("whisper-tiny")
    rng = np.random.default_rng(3)
    S = 12
    toks = rng.integers(0, cfg.vocab_size, size=(1, S + 1))
    frames = jnp.asarray(rng.normal(size=(1, cfg.enc_seq, cfg.d_model)),
                         jnp.float32)
    b_s = {"tokens": jnp.asarray(toks[:, :S], jnp.int32), "frames": frames}
    b_s1 = {"tokens": jnp.asarray(toks, jnp.int32), "frames": frames}
    caches, _ = prefill(cfg, params, b_s, cache_len=S + 1, q_chunk=16,
                        cache_dtype=jnp.float32)
    logits_dec, _ = decode_step(
        cfg, params, jnp.asarray(toks[:, S : S + 1], jnp.int32), caches,
        jnp.int32(S),
    )
    _, logits_par = prefill(cfg, params, b_s1, cache_len=S + 2, q_chunk=16,
                            cache_dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_par), rtol=2e-2, atol=2e-2
    )


def test_param_counts_sane():
    """Full configs match published parameter scales (±15%)."""
    expected = {
        "starcoder2-3b": 3.0e9,
        "gemma3-12b": 12.0e9,
        "gemma2-27b": 27.0e9,
        "gemma2-9b": 9.0e9,
        "llava-next-34b": 34.0e9,
        "olmoe-1b-7b": 7.0e9,
        "mixtral-8x7b": 47.0e9,
        "mamba2-370m": 0.37e9,
        "jamba-v0.1-52b": 52.0e9,
    }
    for name, want in expected.items():
        got = count_params(get_config(name))
        assert abs(got - want) / want < 0.25, (
            f"{name}: {got / 1e9:.2f}B vs expected {want / 1e9:.1f}B"
        )


def test_active_params_moe():
    olmoe = get_config("olmoe-1b-7b")
    active = count_params(olmoe, active_only=True)
    total = count_params(olmoe)
    assert active < total
    # ~1B active of ~7B total
    assert 0.7e9 < active < 1.8e9, f"olmoe active {active / 1e9:.2f}B"


def test_sliding_window_cache_bounded():
    """Local layers must not allocate beyond the window (long-context
    viability)."""
    cfg = get_config("mixtral-8x7b").reduced()
    caches = init_caches(cfg, batch=1, cache_len=1024)
    k = caches["e0"]["k"]  # (U, B, cap, KV, hd)
    assert k.shape[2] == cfg.window  # ring buffer, not 1024
