"""DFG-based discovery (Fig. 1 step 3): filtering, dependency graphs,
footprints, conformance, and mining-of-telemetry."""

import numpy as np

from repro.core import (
    EventCollector,
    EventRepository,
    dependency_matrix,
    dfg_from_repository,
    discover_dependency_graph,
    filter_dfg,
    footprint,
    footprint_conformance,
    to_dot,
)


def _simple_repo():
    # a -> b -> d  and  a -> c -> d, 10 traces each
    return EventRepository.from_traces(
        [["a", "b", "d"]] * 10 + [["a", "c", "d"]] * 10
    )


def test_filter_dfg_thresholds_noise():
    repo = EventRepository.from_traces(
        [["a", "b"]] * 9 + [["a", "c"]]  # a->c is noise
    )
    psi = dfg_from_repository(repo)
    filtered = filter_dfg(psi, min_count=2)
    names = repo.activity_names
    assert filtered[names.index("a"), names.index("c")] == 0
    assert filtered[names.index("a"), names.index("b")] == 9


def test_dependency_matrix_properties():
    psi = dfg_from_repository(_simple_repo())
    dep = dependency_matrix(psi)
    assert dep.shape == psi.shape
    assert (dep <= 1.0).all() and (dep >= -1.0).all()
    # antisymmetry off-diagonal
    off = ~np.eye(psi.shape[0], dtype=bool)
    np.testing.assert_allclose(dep[off], -dep.T[off], atol=1e-12)


def test_discover_dependency_graph_structure():
    repo = _simple_repo()
    psi = dfg_from_repository(repo)
    starts, ends = repo.trace_boundaries()
    model = discover_dependency_graph(
        psi, repo.activity_names, starts, ends, min_count=1, min_dependency=0.5
    )
    assert ("a", "b") in model.edge_set
    assert ("a", "c") in model.edge_set
    assert ("b", "d") in model.edge_set
    assert ("c", "d") in model.edge_set
    assert model.start_activities == {"a": 20}
    assert model.end_activities == {"d": 20}
    dot = to_dot(model)
    assert "digraph" in dot and '"a" -> "b"' in dot


def test_footprint_relations():
    # a->b always, b||c (both orders), d never follows a
    repo = EventRepository.from_traces(
        [["a", "b", "c"], ["a", "c", "b"]]
    )
    psi = dfg_from_repository(repo)
    fp = footprint(psi)
    n = repo.activity_names
    ai, bi, ci = n.index("a"), n.index("b"), n.index("c")
    assert fp[ai, bi] == 1  # a -> b
    assert fp[bi, ai] == 2  # b <- a
    assert fp[bi, ci] == 3  # b || c
    assert fp[ai, ai] == 0  # never


def test_footprint_conformance_metric():
    r1 = _simple_repo()
    psi1 = dfg_from_repository(r1)
    assert footprint_conformance(footprint(psi1), footprint(psi1)) == 1.0
    # perturbed log misses one path
    r2 = EventRepository.from_traces(
        [["a", "b", "d"]] * 20, activity_vocab=r1.activity_names
    )
    c = footprint_conformance(footprint(psi1), footprint(dfg_from_repository(r2)))
    assert 0.0 < c < 1.0


def test_mining_runtime_telemetry():
    """The framework mines its own execution: a healthy loop's DFG is a
    chain; an injected retry shows up as a variant."""
    col = EventCollector()
    for step in range(5):
        case = f"step-{step}"
        for phase in ["load", "forward", "backward", "optim"]:
            col.record(case, phase, timestamp=float(step * 10 + ["load", "forward", "backward", "optim"].index(phase)))
    # inject a retry in step 3
    col.record("step-3", "retry", timestamp=35.5)
    repo = col.to_repository()
    psi = dfg_from_repository(repo)
    names = repo.activity_names
    # the chain edges dominate
    assert psi[names.index("load"), names.index("forward")] == 5
    assert psi[names.index("forward"), names.index("backward")] == 5
    # the deviation is visible
    assert psi[names.index("optim"), names.index("retry")] == 1


def test_straggler_report():
    col = EventCollector()
    for i in range(10):
        col.record(f"s{i}", "grad_sync", timestamp=float(i), duration=1.0)
    col.record("s10", "grad_sync", timestamp=10.0, duration=30.0)  # straggler
    rep = col.straggler_report(threshold=3.0)
    assert "grad_sync" in rep
    assert rep["grad_sync"]["ratio"] > 3.0
