"""Repository forms, canonicalization, conversions, persistence."""

import numpy as np
import pytest

from repro.core import (
    EventRepository,
    check_columnar,
    check_graph,
    paper_example_repo,
)


def test_from_event_table_canonicalizes_unsorted_input():
    # deliberately shuffled rows; two interleaved cases
    cases = ["c2", "c1", "c1", "c2", "c1"]
    acts = ["x", "a", "b", "y", "c"]
    times = [10.0, 1.0, 2.0, 11.0, 3.0]
    repo = EventRepository.from_event_table(cases, acts, times)
    assert check_columnar(repo).ok
    # c1 events first (sorted trace names), in time order
    got = [
        (repo.trace_names[t], repo.activity_names[a])
        for t, a in zip(repo.event_trace, repo.event_activity)
    ]
    assert got == [("c1", "a"), ("c1", "b"), ("c1", "c"), ("c2", "x"), ("c2", "y")]


def test_roundtrip_graph_columnar():
    repo = paper_example_repo()
    g = repo.to_graph()
    assert check_graph(g).ok
    back = g.to_columnar()
    assert check_columnar(back).ok
    # same DFG either way
    from repro.core import dfg_from_repository

    np.testing.assert_array_equal(
        dfg_from_repository(repo), dfg_from_repository(back)
    )


def test_df_pairs_validity():
    repo = EventRepository.from_traces([["a", "b", "c"], ["b", "c"]])
    src, dst, valid = repo.df_pairs()
    assert src.shape == dst.shape == valid.shape == (4,)
    assert valid.tolist() == [True, True, False, True]


def test_padded_pairs_multiple():
    repo = EventRepository.from_traces([["a", "b", "c"], ["b", "c"]])
    src, dst, valid, st, dt = repo.padded_pairs(8)
    assert src.shape == (8,)
    assert valid[4:].sum() == 0


def test_events_of_activity_is_preset_operator():
    repo = paper_example_repo()
    # •a2 = {e2, e4} → indices 1 and 3 in canonical order
    assert repo.events_of_activity("a2").tolist() == [1, 3]


def test_trace_boundaries():
    repo = EventRepository.from_traces([["a", "b"], ["a", "c"], ["b", "c"]])
    starts, ends = repo.trace_boundaries()
    names = repo.activity_names
    assert starts[names.index("a")] == 2
    assert starts[names.index("b")] == 1
    assert ends[names.index("c")] == 2
    assert ends[names.index("b")] == 1


def test_save_load_roundtrip(tmp_path):
    repo = paper_example_repo()
    repo.save(str(tmp_path / "repo"))
    back = EventRepository.load(str(tmp_path / "repo"))
    np.testing.assert_array_equal(repo.event_activity, back.event_activity)
    np.testing.assert_array_equal(repo.event_trace, back.event_trace)
    assert back.activity_names == repo.activity_names


def test_unknown_activity_rejected_with_fixed_vocab():
    with pytest.raises(ValueError):
        EventRepository.from_event_table(
            ["c1"], ["zzz"], [0.0], activity_vocab=["a", "b"]
        )
