"""repro.core.telemetry — collector thread-safety, the ring-buffer mode,
batch recording, StepTimer, and repository conversion ordering."""

import threading

import numpy as np
import pytest

from repro.core.telemetry import EventCollector, StepTimer


def test_record_and_convert_orders_by_case_then_time():
    c = EventCollector("t")
    # interleaved arrival across two cases, timestamps out of arrival order
    c.record("b", "x", timestamp=2.0)
    c.record("a", "q", timestamp=5.0)
    c.record("a", "p", timestamp=1.0)
    c.record("b", "y", timestamp=3.0)
    repo = c.to_repository()
    acts = [repo.activity_names[i] for i in repo.event_activity]
    # from_event_table stably sorts by (case, timestamp)
    assert acts == ["p", "q", "x", "y"]
    assert repo.num_events == 4


def test_concurrent_record_thread_safety():
    c = EventCollector("t")
    N, M = 8, 500

    def work(tid):
        for i in range(M):
            with c.span(f"case-{tid}", "phase"):
                pass
            c.record(f"case-{tid}", "done", timestamp=float(i))

    threads = [threading.Thread(target=work, args=(t,)) for t in range(N)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(c) == N * M * 2
    assert c.dropped == 0
    repo = c.to_repository()
    assert repo.num_events == N * M * 2


def test_ring_buffer_keeps_newest_and_counts_drops():
    c = EventCollector("t", max_events=10)
    for i in range(25):
        c.record("case", f"a{i}", timestamp=float(i))
    assert len(c) == 10
    assert c.dropped == 15
    repo = c.to_repository()
    acts = [repo.activity_names[i] for i in repo.event_activity]
    assert acts == [f"a{i}" for i in range(15, 25)]  # newest 10 retained


def test_record_many_broadcasts_case_and_batches():
    c = EventCollector("t", max_events=5)
    c.record_many("q1", ["a", "b", "c"], [1.0, 2.0, 3.0])
    c.record_many(["q2", "q3"], ["d", "e"], [4.0, 5.0], durations=[0.1, 0.2])
    assert len(c) == 5 and c.dropped == 0
    c.record_many("q4", ["f", "g"], [6.0, 7.0])
    assert len(c) == 5 and c.dropped == 2
    repo = c.to_repository()
    acts = [repo.activity_names[i] for i in repo.event_activity]
    assert acts == ["c", "d", "e", "f", "g"]


def test_unbounded_by_default():
    c = EventCollector("t")
    for i in range(10_000):
        c.record("case", "a", timestamp=float(i))
    assert len(c) == 10_000 and c.dropped == 0


def test_span_records_duration():
    c = EventCollector("t")
    with c.span("case", "work"):
        pass
    ds = c.durations_by_activity()
    assert "work" in ds and ds["work"].shape == (1,)
    assert ds["work"][0] >= 0.0


def test_straggler_report_flags_outlier():
    c = EventCollector("t")
    for i in range(6):
        c.record("case", "fast", timestamp=float(i), duration=0.01)
    c.record("case", "fast", timestamp=99.0, duration=1.0)
    rep = c.straggler_report(threshold=3.0)
    assert "fast" in rep and rep["fast"]["ratio"] > 3.0


def test_step_timer_totals_and_counts():
    t = StepTimer()
    for _ in range(3):
        with t.phase("load"):
            pass
    with t.phase("fwd"):
        pass
    s = t.summary()
    assert s["load"][1] == 3 and s["fwd"][1] == 1
    assert s["load"][0] >= 0.0
    assert set(t.counts) == {"load", "fwd"}
