"""repro.query — plan equivalence vs the Algorithm 1 oracle, optimizer
rewrites, backend cost model, and the plan/result cache."""

import dataclasses
import json
import os
import shutil

import numpy as np
import pytest

from repro.core import (
    ActivityView,
    dfg_algorithm1,
    dfg_numpy,
    dice_repository,
    paper_example_repo,
    streaming_dfg,
)
from repro.core.dicing import pair_mask_for_window
from repro.core.streaming import MemmapLog
from repro.core.variants import trace_variants, variant_filtered_repository
from repro.data import ProcessSpec, generate_memmap_log, generate_repository
from repro.query import (
    Q,
    QueryCache,
    QueryEngine,
    QueryPlanError,
    canonicalize,
    fingerprint,
)
from repro.query.ast import DFGSink, Window
from repro.query.execute import repository_from_memmap


@pytest.fixture()
def engine():
    return QueryEngine()


@pytest.fixture(scope="module")
def repo():
    return generate_repository(400, ProcessSpec(num_activities=11, seed=9))


def _reference_dfg(repo, window=None, keep=None, view=None):
    """Naive single-backend evaluation: pair masks + oracle counting +
    post-hoc projection.  Every optimized plan must match this bit-exactly."""
    src, dst, valid = repo.df_pairs()
    if window is not None:
        valid = valid & pair_mask_for_window(repo, window)
    if keep is not None:
        ids = np.asarray([repo.activity_names.index(a) for a in keep])
        m = np.isin(repo.event_activity, ids)
        valid = valid & m[:-1] & m[1:]
    psi = dfg_numpy(src, dst, valid, repo.num_activities)
    if view is not None:
        psi = view.apply_to_dfg(psi, repo.activity_names)
    return psi


# ---------------------------------------------------------------------------
# plan equivalence — the acceptance criterion
# ---------------------------------------------------------------------------


def test_paper_example_matches_algorithm1(engine):
    repo = paper_example_repo()
    want, _ = dfg_algorithm1(repo.to_graph())
    for backend in ("auto", "numpy", "scatter", "onehot", "pallas"):
        res = Q.log(repo).using(engine).dfg(backend=backend)
        np.testing.assert_array_equal(res.value, want)
        assert res.names == repo.activity_names


@pytest.mark.parametrize("backend", ["numpy", "scatter", "onehot", "pallas"])
def test_windowed_query_equals_oracle(repo, engine, backend):
    t0 = float(np.quantile(repo.event_time, 0.3))
    t1 = float(np.quantile(repo.event_time, 0.8))
    res = Q.log(repo).using(engine).window(t0, t1).dfg(backend=backend)
    np.testing.assert_array_equal(
        res.value, _reference_dfg(repo, window=(t0, t1))
    )


def test_activity_filter_equals_pair_predicate(repo, engine):
    keep = repo.activity_names[2:7]
    res = Q.log(repo).using(engine).activities(keep).dfg()
    assert res.physical.activities_as_output_mask
    np.testing.assert_array_equal(res.value, _reference_dfg(repo, keep=keep))


def test_view_pushdown_equals_post_projection(repo, engine):
    names = repo.activity_names
    view = ActivityView(
        {a: f"g{i % 3}" for i, a in enumerate(names[:-2])}  # last 2 hidden
    )
    res = Q.log(repo).using(engine).view(view).dfg()
    assert res.physical.view_pushdown  # counted in G×G space
    np.testing.assert_array_equal(res.value, _reference_dfg(repo, view=view))
    assert res.names == view.visible_names(names)


def test_combined_window_filter_view(repo, engine):
    t0 = float(np.quantile(repo.event_time, 0.2))
    t1 = float(np.quantile(repo.event_time, 0.9))
    keep = repo.activity_names[1:8]
    view = ActivityView({a: a[-1] for a in repo.activity_names[:9]})
    res = (
        Q.log(repo).using(engine)
        .window(t0, t1).activities(keep).view(view).dfg()
    )
    np.testing.assert_array_equal(
        res.value, _reference_dfg(repo, window=(t0, t1), keep=keep, view=view)
    )


def test_fused_pallas_dicing_equals_oracle(engine):
    # integer timestamps (f32-exact) so the kernel's f32 WHERE clause is
    # bit-identical to the f64 host mask
    repo = generate_repository(300, ProcessSpec(num_activities=7, seed=2))
    repo = dataclasses.replace(
        repo, event_time=np.floor(repo.event_time / 3600.0)
    )
    window = (10.0, 500.0)
    res = Q.log(repo).using(engine).window(*window).dfg(backend="pallas")
    assert res.physical.fused_dicing
    np.testing.assert_array_equal(
        res.value, _reference_dfg(repo, window=window)
    )


def test_relink_activities_matches_dice_repository(repo, engine):
    keep = repo.activity_names[:6]
    res = Q.log(repo).using(engine).activities(keep, relink=True).dfg()
    want = _reference_dfg(dice_repository(repo, activities=keep))
    np.testing.assert_array_equal(res.value, want)


def test_top_variants_op(repo, engine):
    res = Q.log(repo).using(engine).top_variants(3).dfg()
    want = _reference_dfg(variant_filtered_repository(repo, 3))
    np.testing.assert_array_equal(res.value, want)


def test_variants_sink(repo, engine):
    res = Q.log(repo).using(engine).variants(5)
    tv = trace_variants(repo)
    np.testing.assert_array_equal(res.value.counts, tv.counts[:5])
    assert res.value.sequences == tv.sequences[:5]


def test_histogram_sink(repo, engine):
    res = Q.log(repo).using(engine).histogram()
    want = np.bincount(repo.event_activity, minlength=repo.num_activities)
    np.testing.assert_array_equal(res.value, want)


def test_distributed_backend_equals_oracle(repo):
    from repro.launch.mesh import make_test_mesh

    eng = QueryEngine(mesh=make_test_mesh((1,), ("data",)))
    res = Q.log(repo).using(eng).dfg()
    assert res.physical.backend == "distributed"
    np.testing.assert_array_equal(res.value, _reference_dfg(repo))


# ---------------------------------------------------------------------------
# memmap / streaming
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mmlog(tmp_path_factory):
    path = tmp_path_factory.mktemp("qlog") / "mm"
    return generate_memmap_log(
        str(path), 25_000, ProcessSpec(num_activities=13, seed=31), seed=31,
        batch_traces=400,
    )


def test_streaming_plan_equals_direct_call(mmlog):
    eng = QueryEngine(memory_budget_events=100)  # force out-of-core
    res = Q.log(mmlog).using(eng).dfg()
    assert res.physical.backend == "streaming"
    np.testing.assert_array_equal(res.value, streaming_dfg(mmlog))


def test_streaming_window_row_range_pushdown(mmlog):
    eng = QueryEngine(memory_budget_events=100)
    t0 = float(np.quantile(np.asarray(mmlog.time), 0.25))
    t1 = float(np.quantile(np.asarray(mmlog.time), 0.75))
    res = Q.log(mmlog).using(eng).window(t0, t1).dfg()
    assert res.physical.row_range_window == (t0, t1)
    np.testing.assert_array_equal(
        res.value, streaming_dfg(mmlog, time_window=(t0, t1))
    )


def test_materialized_memmap_equals_streaming(mmlog):
    """Under the memory budget the cost model loads the log and uses a
    device backend — counts must be identical to the out-of-core scan."""
    res = Q.log(mmlog).using(QueryEngine()).dfg()
    assert res.physical.materialize and res.physical.backend != "streaming"
    np.testing.assert_array_equal(res.value, streaming_dfg(mmlog))


def test_memmap_window_matches_repository_semantics(mmlog):
    """Row-range dicing on the time-ordered stream == pair-endpoint masking
    on the materialized repository (paper semantics)."""
    t0 = float(np.quantile(np.asarray(mmlog.time), 0.4))
    t1 = float(np.quantile(np.asarray(mmlog.time), 0.9))
    stream = Q.log(mmlog).using(
        QueryEngine(memory_budget_events=100)
    ).window(t0, t1).dfg()
    repo = repository_from_memmap(mmlog)
    np.testing.assert_array_equal(
        stream.value, _reference_dfg(repo, window=(t0, t1))
    )


def test_streaming_histogram(mmlog):
    eng = QueryEngine(memory_budget_events=100)
    res = Q.log(mmlog).using(eng).histogram()
    want = np.zeros(mmlog.num_activities, np.int64)
    for a, _, _ in mmlog.iter_chunks():
        want += np.bincount(a, minlength=mmlog.num_activities)
    np.testing.assert_array_equal(res.value, want)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_window_fusion_and_canonical_order(repo, engine):
    q1 = Q.log(repo).window(0.0, 1e9).window(5e5, 2e9).activities(
        repo.activity_names[:4]
    )
    q2 = Q.log(repo).activities(repo.activity_names[:4]).window(5e5, 1e9)
    p1, notes = canonicalize(q1.logical_plan(DFGSink()))
    p2, _ = canonicalize(q2.logical_plan(DFGSink()))
    assert "fuse_windows" in notes
    windows = [op for op in p1.ops if isinstance(op, Window)]
    assert windows == [Window(5e5, 1e9)]
    # differently chained but equivalent queries share one cache key
    assert p1.key() == p2.key()
    r1 = q1.using(engine).dfg()
    r2 = q2.using(engine).dfg()
    assert r2.from_cache
    np.testing.assert_array_equal(r1.value, r2.value)
    np.testing.assert_array_equal(
        r1.value, _reference_dfg(repo, window=(5e5, 1e9),
                                 keep=repo.activity_names[:4])
    )


def test_view_composition(repo, engine):
    v1 = ActivityView({a: f"g{i % 4}" for i, a in enumerate(repo.activity_names)})
    v2 = ActivityView({"g0": "x", "g1": "x"})  # g2, g3 fall to HIDDEN
    res = Q.log(repo).using(engine).view(v1).view(v2).dfg()
    psi1 = _reference_dfg(repo, view=v1)
    want = v2.apply_to_dfg(psi1, v1.visible_names(repo.activity_names))
    np.testing.assert_array_equal(res.value, want)


def test_drop_noop_rewrites(repo):
    q = Q.log(repo).window(-np.inf, np.inf).activities(repo.activity_names)
    plan, notes = canonicalize(
        q.logical_plan(DFGSink()), repo.activity_names
    )
    assert plan.ops == ()
    assert "drop_infinite_window" in notes
    assert "drop_keep_all_filter" in notes


def test_errors(repo, engine, mmlog):
    view = ActivityView({repo.activity_names[0]: "g"})
    with pytest.raises(QueryPlanError):
        Q.log(repo).using(engine).view(view).activities(["a"]).dfg()
    with pytest.raises(QueryPlanError):
        Q.log(repo).using(engine).activities(["not-an-activity"]).dfg()
    with pytest.raises(QueryPlanError):
        Q.log(repo).using(engine).dfg(backend="streaming")
    with pytest.raises(QueryPlanError):
        Q.log(repo).using(engine).view(view).variants()
    with pytest.raises(QueryPlanError):
        # materializing ops cannot run out-of-core
        Q.log(mmlog).using(
            QueryEngine(memory_budget_events=100)
        ).top_variants(2).dfg()
    with pytest.raises(QueryPlanError):
        # a view cannot be hoisted across a materialization barrier —
        # top_variants would rank raw variants, not projected ones
        Q.log(repo).using(engine).view(view).top_variants(1).dfg()
    with pytest.raises(QueryPlanError):
        Q.log(repo).using(engine).view(view).activities(
            [repo.activity_names[0]], relink=True
        ).dfg()
    with pytest.raises(QueryPlanError):
        # a pinned device backend must not slurp an out-of-core log
        Q.log(mmlog).using(
            QueryEngine(memory_budget_events=100)
        ).dfg(backend="scatter")


def test_explain_mentions_pushdown(mmlog):
    eng = QueryEngine(memory_budget_events=100)
    txt = Q.log(mmlog).using(eng).window(0.0, 1.0).explain()
    assert "row_range" in txt
    assert "streaming" in txt


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------


def test_cache_hit_skips_execution(repo, engine, monkeypatch):
    t0 = float(np.quantile(repo.event_time, 0.1))
    q = Q.log(repo).using(engine).window(t0, t0 + 1e6)
    first = q.dfg()
    assert not first.from_cache and engine.stats.executions == 1

    def boom(*a, **k):  # any re-execution is a bug
        raise AssertionError("executor ran on a cached plan")

    monkeypatch.setattr(engine, "_execute", boom)
    second = q.dfg()
    assert second.from_cache
    assert engine.stats.cache_hits == 1 and engine.stats.executions == 1
    np.testing.assert_array_equal(first.value, second.value)


def test_cache_is_content_addressed(repo, engine):
    """An equal copy of the repository hits; appending one event misses."""
    clone = dataclasses.replace(
        repo,
        event_activity=repo.event_activity.copy(),
        event_time=repo.event_time.copy(),
    )
    Q.log(repo).using(engine).dfg()
    assert Q.log(clone).using(engine).dfg().from_cache

    grown = dataclasses.replace(
        repo,
        event_activity=np.append(repo.event_activity, 0).astype(np.int32),
        event_trace=np.append(
            repo.event_trace, repo.event_trace[-1]
        ).astype(np.int32),
        event_time=np.append(repo.event_time, repo.event_time[-1] + 1.0),
    )
    res = Q.log(grown).using(engine).dfg()
    assert not res.from_cache


def test_memmap_fingerprint_changes_after_append(mmlog, tmp_path):
    """Appending rows to the disk tier invalidates every cached result."""
    path = str(tmp_path / "copy")
    shutil.copytree(mmlog.path, path)
    log = MemmapLog.open(path)
    fp_before = fingerprint(log)

    eng = QueryEngine(memory_budget_events=100)
    assert not Q.log(log).using(eng).dfg().from_cache
    assert Q.log(log).using(eng).dfg().from_cache

    # append one event to each column + bump the row count
    with open(os.path.join(path, "activity.i32"), "ab") as f:
        f.write(np.asarray([1], np.int32).tobytes())
    with open(os.path.join(path, "case.i32"), "ab") as f:
        f.write(np.asarray([0], np.int32).tobytes())
    with open(os.path.join(path, "time.f64"), "ab") as f:
        f.write(np.asarray([float(log.time[-1]) + 1.0], np.float64).tobytes())
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    meta["num_events"] += 1
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)

    appended = MemmapLog.open(path)
    assert fingerprint(appended) != fp_before
    res = Q.log(appended).using(eng).dfg()
    assert not res.from_cache  # recomputed on the appended log
    np.testing.assert_array_equal(res.value, streaming_dfg(appended))


def test_cached_results_are_isolated(repo, engine):
    first = Q.log(repo).using(engine).dfg()
    first.value[:] = -1  # caller scribbles on its copy
    second = Q.log(repo).using(engine).dfg()
    assert second.from_cache
    assert (second.value >= 0).all()
    np.testing.assert_array_equal(second.value, _reference_dfg(repo))


def test_cache_lru_eviction(repo):
    eng = QueryEngine(cache=QueryCache(max_entries=2))
    qs = [Q.log(repo).using(eng).window(0.0, float(t)) for t in (1e5, 2e5, 3e5)]
    for q in qs:
        q.dfg()
    assert len(eng.cache) == 2
    assert not qs[0].dfg().from_cache  # evicted
    assert eng.cache.stats.evictions >= 1


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------


def test_query_service_end_to_end(repo, mmlog):
    from repro.core.views import AccessPolicy
    from repro.serve import QueryService

    svc = QueryService()
    svc.register("main", repo)
    svc.register("disk", mmlog)
    svc.register(
        "locked", repo,
        policy=AccessPolicy(time_windows_allowed=False),
    )
    out = svc.query({"log": "main", "sink": "dfg"})
    np.testing.assert_array_equal(np.asarray(out["psi"]), _reference_dfg(repo))
    assert not out["from_cache"]
    assert svc.query({"log": "main", "sink": "dfg"})["from_cache"]

    hist = svc.query({"log": "disk", "sink": "histogram"})
    assert sum(hist["counts"]) == mmlog.num_events

    var = svc.query({"log": "main", "sink": "variants", "k": 2})
    assert len(var["sequences"]) <= 2
    # wire-friendly: JSON/query-param values arrive as strings
    var_s = svc.query({"log": "main", "sink": "variants", "k": "2"})
    assert var_s["counts"] == var["counts"]

    from repro.core.views import AccessDenied

    with pytest.raises(AccessDenied):
        svc.query({"log": "locked", "sink": "dfg", "window": [0.0, 1.0]})


def test_query_service_view_policy_guards(repo):
    """A coarsening view must not be bypassable via raw-activity filters or
    raw variant sequences, and min_group_count suppresses all sinks."""
    from repro.core.views import AccessDenied, AccessPolicy
    from repro.serve import QueryService

    view = ActivityView({a: "g" for a in repo.activity_names[:4]})
    svc = QueryService()
    svc.register("v", repo, policy=AccessPolicy(view=view))
    svc.register("k", repo, policy=AccessPolicy(min_group_count=10**9))

    with pytest.raises(AccessDenied):
        svc.query({"log": "v", "sink": "dfg",
                   "activities": [repo.activity_names[0]]})
    with pytest.raises(AccessDenied):
        svc.query({"log": "v", "sink": "variants"})

    assert sum(svc.query({"log": "k", "sink": "histogram"})["counts"]) == 0
    assert not np.asarray(svc.query({"log": "k", "sink": "dfg"})["psi"]).any()
    assert svc.query({"log": "k", "sink": "variants"})["sequences"] == []


def test_analyst_session_through_engine(repo):
    from repro.core import AccessPolicy, AnalystSession

    view = ActivityView({a: a for a in repo.activity_names[:5]})
    ses = AnalystSession(repo, AccessPolicy(view=view))
    psi, names = ses.dfg()
    assert names == view.visible_names(repo.activity_names)
    np.testing.assert_array_equal(psi, _reference_dfg(repo, view=view))
    counts, names2 = ses.activity_histogram()
    assert names2 == names
    full = np.bincount(repo.event_activity, minlength=repo.num_activities)
    np.testing.assert_array_equal(counts, full[:5])
