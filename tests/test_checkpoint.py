"""Checkpointing: roundtrip, async, retention, atomicity, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)},
        "opt": (jnp.int32(7), {"mu": jnp.asarray(rng.normal(size=(8, 4)))}),
    }


def test_roundtrip_sync(tmp_path):
    m = CheckpointManager(str(tmp_path), async_writes=False)
    t = _tree()
    m.save(10, t, metadata={"next_step": 10})
    restored, meta = m.restore(template=jax.eval_shape(lambda: t))
    assert meta["next_step"] == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_waits(tmp_path):
    m = CheckpointManager(str(tmp_path), async_writes=True)
    for s in (1, 2, 3):
        m.save(s, _tree(s))
    m.wait()
    assert m.all_steps() == [1, 2, 3]


def test_retention(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2, async_writes=False)
    for s in range(5):
        m.save(s, _tree(s))
    assert m.all_steps() == [3, 4]


def test_latest_and_restore_specific(tmp_path):
    m = CheckpointManager(str(tmp_path), async_writes=False)
    m.save(1, _tree(1))
    m.save(2, _tree(2))
    assert m.latest_step() == 2
    t1, _ = m.restore(1, template=jax.eval_shape(lambda: _tree(1)))
    np.testing.assert_array_equal(
        np.asarray(t1["params"]["w"]),
        np.asarray(_tree(1)["params"]["w"]),
    )


def test_no_partial_checkpoints(tmp_path):
    """tmp- dirs are never left as valid steps (atomic rename)."""
    m = CheckpointManager(str(tmp_path), async_writes=False)
    m.save(5, _tree())
    names = os.listdir(tmp_path)
    assert "step-5" in names
    assert not any(n.startswith("tmp-") for n in names)


def test_elastic_restore_resharding(tmp_path):
    """Restore with explicit shardings (different 'mesh') — the elastic
    path.  On 1 CPU device the mesh is trivial, but the code path (device_put
    with target NamedSharding) is the same one a resized pod uses."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.compat import make_mesh

    mesh = make_mesh((1,), ("data",), devices=jax.devices()[:1])
    m = CheckpointManager(str(tmp_path), async_writes=False)
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    m.save(1, t)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = m.restore(shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))
    assert restored["w"].sharding == sh["w"]


def test_missing_checkpoint_raises(tmp_path):
    m = CheckpointManager(str(tmp_path), async_writes=False)
    with pytest.raises(FileNotFoundError):
        m.restore(template={})
