"""Trace-variant analysis (paper §5.2 spaghetti-model remedy)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import EventRepository, check_columnar, dfg_from_repository
from repro.core.variants import trace_variants, variant_filtered_repository
from repro.data import ProcessSpec, generate_repository


def test_variants_basic():
    repo = EventRepository.from_traces(
        [["a", "b", "c"]] * 5 + [["a", "c"]] * 3 + [["b"]] * 1
    )
    tv = trace_variants(repo)
    assert tv.num_variants == 3
    assert tv.counts.tolist() == [5, 3, 1]
    assert tv.sequences[0] == ["a", "b", "c"]
    assert tv.sequences[1] == ["a", "c"]
    assert abs(tv.coverage(1) - 5 / 9) < 1e-9
    assert tv.coverage(3) == 1.0


def test_variants_distinguish_order_and_length():
    repo = EventRepository.from_traces(
        [["a", "b"], ["b", "a"], ["a", "b", "b"], ["a", "b"]]
    )
    tv = trace_variants(repo)
    assert tv.num_variants == 3
    assert tv.counts.tolist() == [2, 1, 1]


def test_variant_filter_keeps_sound_repo():
    repo = generate_repository(300, ProcessSpec(num_activities=10, seed=6))
    tv = trace_variants(repo)
    filt = variant_filtered_repository(repo, keep_top=5)
    assert check_columnar(filt).ok
    assert filt.num_traces == int(tv.counts[:5].sum())
    # filtered DFG is a "sub-flow" of the full DFG
    assert (dfg_from_repository(filt) <= dfg_from_repository(repo)).all()


@settings(max_examples=30, deadline=None)
@given(
    traces=st.lists(
        st.lists(st.sampled_from(["a", "b", "c", "d"]), min_size=1, max_size=8),
        min_size=1, max_size=30,
    )
)
def test_variants_property_counts(traces):
    """Variant counts must match a reference dict-of-tuples computation."""
    repo = EventRepository.from_traces(traces)
    tv = trace_variants(repo)
    from collections import Counter

    ref = Counter(tuple(tr) for tr in traces)
    assert tv.num_variants == len(ref)
    assert sorted(tv.counts.tolist(), reverse=True) == sorted(
        ref.values(), reverse=True
    )
    assert int(tv.counts.sum()) == len(traces)


def test_empty_repo():
    repo = EventRepository.from_traces([])
    tv = trace_variants(repo)
    assert tv.num_variants == 0
