"""launch/steps.py integration: every step kind lowers + compiles on a
1×1 (data, model) test mesh with reduced configs — the same builder code
the production dry-run uses, exercised in-process."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import (
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

TRAIN = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
PREFILL = ShapeConfig("p", seq_len=32, global_batch=2, kind="prefill")
DECODE = ShapeConfig("d", seq_len=32, global_batch=2, kind="decode")

ARCHS = ["starcoder2-3b", "gemma2-9b", "mixtral-8x7b", "mamba2-370m",
         "jamba-v0.1-52b", "whisper-tiny", "llava-next-34b"]


def _cfg(arch):
    cfg = get_config(arch).reduced()
    # reduced shapes must divide the tiny seq len
    return dataclasses.replace(
        cfg, vocab_size=128, loss_chunk=16, q_chunk=16,
        microbatches=2 if cfg.n_experts else 1, ssm_chunk=8,
    )


@pytest.fixture(scope="module")
def mesh():
    return make_test_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_lowers(arch, mesh):
    cfg = _cfg(arch)
    fn, in_sh, out_sh, args, donate = make_train_step(cfg, TRAIN, mesh)
    compiled = jax.jit(
        fn, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate
    ).lower(*args).compile()
    from repro.core.compat import cost_analysis

    assert cost_analysis(compiled).get("flops", 0) > 0


@pytest.mark.parametrize("arch", ["gemma2-9b", "mixtral-8x7b", "whisper-tiny"])
def test_prefill_step_lowers(arch, mesh):
    cfg = _cfg(arch)
    fn, in_sh, out_sh, args, donate = make_prefill_step(cfg, PREFILL, mesh)
    compiled = jax.jit(
        fn, in_shardings=in_sh, out_shardings=out_sh
    ).lower(*args).compile()
    assert compiled is not None


@pytest.mark.parametrize("arch", ["gemma2-9b", "mamba2-370m", "jamba-v0.1-52b"])
def test_decode_step_lowers_and_runs(arch, mesh):
    cfg = _cfg(arch)
    fn, in_sh, out_sh, args, donate = make_decode_step(cfg, DECODE, mesh)
    jitted = jax.jit(
        fn, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate
    )
    compiled = jitted.lower(*args).compile()
    assert compiled is not None
    # and actually execute it with concrete zeros on the 1-device mesh
    import jax.numpy as jnp

    from repro.models import init_caches, init_params

    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.zeros((DECODE.global_batch, 1), jnp.int32)
    caches = init_caches(cfg, DECODE.global_batch, DECODE.seq_len)
    logits, new_caches = jitted(params, toks, caches, jnp.int32(3))
    assert np.isfinite(np.asarray(logits, np.float32)).all()
