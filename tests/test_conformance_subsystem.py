"""repro.conformance — graph-native / streaming / columnar conformance.

Pins the subsystem's core contract: every evaluation path (columnar oracle,
streaming replayer, graph event-table walk) produces **bit-identical**
trace_fitness arrays and deviation censuses on shared inputs — across
windows, views, activity filters, unions, and append + delta resume — and
the engine plans/caches them like any other sink.
"""

import os

import numpy as np
import pytest

from repro.conformance import (
    ModelSpec,
    StreamingModelDiscoverer,
    StreamingReplayer,
    align_repository,
    alignment_cost_tables,
    replay_fitness_graph,
    replay_fitness_streaming,
)
from repro.core.conformance import (
    deviation_census,
    model_tables,
    replay_fitness,
)
from repro.core.dfg import dfg_numpy
from repro.core.dicing import dice_repository
from repro.core.discovery import discover_dependency_graph
from repro.core.repository import EventRepository
from repro.core.streaming import MemmapLog
from repro.data import ProcessSpec, generate_memmap_log, generate_repository
from repro.graph import build_graph
from repro.kernels.align_dp import align_dp
from repro.query import Q, QueryEngine, QueryPlanError
from repro.query.execute import repository_from_memmap
from repro.query.planner import load_calibration


def _discover(repo, **kw):
    s, d, v = repo.df_pairs()
    psi = dfg_numpy(s, d, v, repo.num_activities)
    starts, ends = repo.trace_boundaries()
    return discover_dependency_graph(
        psi, repo.activity_names, starts, ends,
        min_count=kw.get("min_count", 1),
        min_dependency=kw.get("min_dependency", -1.0),
    )


@pytest.fixture()
def mmlog(tmp_path):
    return generate_memmap_log(
        str(tmp_path / "mm"), 30_000,
        ProcessSpec(num_activities=12, seed=11), seed=11, batch_traces=600,
    )


def _append_noise(log: MemmapLog, n: int, seed: int = 7) -> MemmapLog:
    rng = np.random.default_rng(seed)
    last_t = float(np.asarray(log.time[-1])) if log.num_events else 0.0
    a = rng.integers(0, log.num_activities, n).astype(np.int32)
    c = rng.integers(0, log.num_traces, n).astype(np.int32)
    t = np.sort(rng.uniform(last_t, last_t + 500.0, n))
    return log.append(a, c, t)


# ---------------------------------------------------------------------------
# core oracle edge cases (satellite)
# ---------------------------------------------------------------------------


def test_single_event_traces():
    repo = EventRepository.from_traces([["a"], ["b"], ["a"]])
    model = _discover(EventRepository.from_traces([["a"]] * 5,
                                                  activity_vocab=["a", "b"]))
    res = replay_fitness(repo, model)
    # single-event trace: denom = 2 (start + end); "a" fits both, "b" neither
    np.testing.assert_array_equal(res.trace_fitness, [1.0, 0.0, 1.0])
    assert res.deviating_edges == {}


def test_log_activities_absent_from_model_and_vice_versa():
    model = ModelSpec(
        activities=("a", "b", "ghost"),
        edges=(("a", "b"), ("b", "ghost")),
        starts=("a", "ghost"), ends=("b", "ghost"),
    )
    repo = EventRepository.from_traces(
        [["a", "b"], ["a", "x", "b"]], activity_vocab=["a", "b", "x"]
    )
    res = replay_fitness(repo, model)
    # "ghost" never observed: harmless; "x" unknown to the model: both its
    # moves deviate
    assert res.trace_fitness[0] == 1.0
    assert res.trace_fitness[1] == pytest.approx(2 / 4)
    assert res.deviating_edges == {("a", "x"): 1, ("x", "b"): 1}
    allowed, start_ok, end_ok = model_tables(model, repo.activity_names)
    assert allowed.shape == (3, 3) and not allowed[:, 2].any()


def test_empty_repository_everywhere():
    repo = EventRepository.from_traces([])
    model = _discover(generate_repository(5, ProcessSpec(num_activities=3,
                                                         seed=1)))
    res = replay_fitness(repo, model)
    assert res.fitness == 1.0 and res.trace_fitness.shape == (0,)
    eng = QueryEngine()
    r = Q.log(repo).using(eng).fitness(model)
    assert r.value.fitness == 1.0
    a = Q.log(repo).using(eng).alignments(model)
    assert a.value.fitness == 1.0 and a.value.trace_cost.shape == (0,)


def test_census_vectorized_matches_host_loop():
    rng = np.random.default_rng(3)
    names = [f"a{i}" for i in range(9)]
    src = rng.integers(0, 9, 5000)
    dst = rng.integers(0, 9, 5000)
    want = {}
    for s, d in zip(src, dst):
        k = (names[int(s)], names[int(d)])
        want[k] = want.get(k, 0) + 1
    assert deviation_census(src, dst, names) == want
    assert deviation_census(np.zeros(0, np.int64), np.zeros(0, np.int64),
                            names) == {}


# ---------------------------------------------------------------------------
# three-path bit-identity (the tentpole contract)
# ---------------------------------------------------------------------------


def test_streaming_and_graph_replay_match_oracle(mmlog):
    repo = repository_from_memmap(mmlog)
    model = _discover(repo, min_count=40, min_dependency=0.3)
    oracle = replay_fitness(repo, model)
    stream = replay_fitness_streaming(mmlog, model)
    g = build_graph(mmlog)
    graph = replay_fitness_graph(g, model)
    for other in (stream, graph):
        np.testing.assert_array_equal(
            oracle.trace_fitness, other.trace_fitness
        )
        assert oracle.deviating_edges == other.deviating_edges
    assert oracle.fitness == stream.fitness == graph.fitness


def test_topology_only_graph_rejects_replay(mmlog):
    g = build_graph(mmlog, memory_budget_events=100)
    assert not g.has_event_tables
    model = ModelSpec(activities=("act_000",), edges=(), starts=(), ends=())
    with pytest.raises(ValueError):
        replay_fitness_graph(g, model)


@pytest.mark.parametrize("backend", ["numpy", "streaming", "graph"])
def test_engine_backends_match_diced_oracle(mmlog, backend):
    """Windows under conformance use sequence (re-link) semantics: every
    engine path equals replay of the pm4py-diced repository."""
    repo = repository_from_memmap(mmlog)
    model = _discover(repo, min_count=40, min_dependency=0.3)
    ts = np.asarray(mmlog.time)
    t0, t1 = float(np.quantile(ts, 0.15)), float(np.quantile(ts, 0.7))
    oracle = replay_fitness(
        dice_repository(repo, time_window=(t0, t1)), model
    )
    res = Q.log(mmlog).using(QueryEngine()).window(t0, t1).fitness(
        model, backend=backend
    )
    assert res.physical.backend == (
        "numpy" if backend == "numpy" else backend
    )
    np.testing.assert_array_equal(
        res.value.trace_fitness, oracle.trace_fitness
    )
    assert res.value.deviating_edges == oracle.deviating_edges


def test_view_and_filter_paths_identical(mmlog):
    repo = repository_from_memmap(mmlog)
    names = repo.activity_names
    view = {
        n: ("g0" if i % 3 == 0 else "g1" if i % 3 == 1 else "<hidden>")
        for i, n in enumerate(names)
    }
    keep = list(names[:9])
    vals = {}
    for backend in ("numpy", "streaming", "graph"):
        res = Q.log(mmlog).using(QueryEngine()).activities(keep).view(
            view
        ).fitness(None, backend=backend)
        vals[backend] = res.value
        assert res.names == ["g0", "g1"]
    base = vals["numpy"]
    for backend in ("streaming", "graph"):
        np.testing.assert_array_equal(
            base.trace_fitness, vals[backend].trace_fitness
        )
        assert base.deviating_edges == vals[backend].deviating_edges


def test_property_sweep_append_and_delta_resume(tmp_path):
    """Seeded sweep: streaming/graph/columnar replay bit-identical,
    including after an append served by the delta path (suffix-only scan
    asserted through engine stats)."""
    for seed in (2, 13, 29):
        log = generate_memmap_log(
            str(tmp_path / f"s{seed}"), 12_000,
            ProcessSpec(num_activities=8, seed=seed), seed=seed,
            batch_traces=400,
        )
        repo = repository_from_memmap(log)
        model = _discover(repo, min_count=25, min_dependency=0.2)
        eng = QueryEngine(
            memory_budget_events=2_000, replay_crossover=2_000
        )  # force streaming
        r1 = Q.log(log).using(eng).fitness(model)
        assert r1.physical.backend == "streaming"
        base_rows = eng.stats.rows_scanned

        grown = _append_noise(log, 700, seed=seed)
        r2 = Q.log(grown).using(eng).fitness(model)
        assert r2.physical.backend == "delta"
        assert eng.stats.delta_hits == 1
        assert eng.stats.rows_scanned - base_rows == 700  # suffix only

        repo2 = repository_from_memmap(grown)
        oracle = replay_fitness(repo2, model)
        np.testing.assert_array_equal(
            r2.value.trace_fitness, oracle.trace_fitness
        )
        assert r2.value.deviating_edges == oracle.deviating_edges
        stream = replay_fitness_streaming(grown, model)
        graph = replay_fitness_graph(build_graph(grown), model)
        np.testing.assert_array_equal(
            stream.trace_fitness, oracle.trace_fitness
        )
        np.testing.assert_array_equal(
            graph.trace_fitness, oracle.trace_fitness
        )


def test_default_model_not_delta_resumed(mmlog, tmp_path):
    """model=None re-discovers from the grown log: the engine must fall
    back to a full replay (delta would score against a stale model)."""
    eng = QueryEngine(memory_budget_events=2_000, replay_crossover=2_000)
    r1 = Q.log(mmlog).using(eng).fitness()
    assert r1.physical.backend == "streaming"
    grown = _append_noise(mmlog, 500)
    r2 = Q.log(grown).using(eng).fitness()
    assert r2.physical.backend == "streaming"  # full replay, no delta
    assert eng.stats.delta_hits == 0
    # and it equals a from-scratch default-model replay
    disc = StreamingModelDiscoverer(grown.num_activities)
    for a, c, t in grown.iter_chunks():
        disc.update(a, c, t)
    model = disc.finalize(grown.activity_labels())
    want = replay_fitness_streaming(grown, model)
    np.testing.assert_array_equal(
        r2.value.trace_fitness, want.trace_fitness
    )


def test_free_rewrite_for_windowed_fitness(mmlog):
    """A pinned-model windowed fitness whose window predates the append is
    served from cache with zero additional scan."""
    repo = repository_from_memmap(mmlog)
    model = _discover(repo, min_count=40)
    ts = np.asarray(mmlog.time)
    t0, t1 = float(np.quantile(ts, 0.1)), float(np.quantile(ts, 0.5))
    eng = QueryEngine(memory_budget_events=2_000, replay_crossover=2_000)
    r1 = Q.log(mmlog).using(eng).window(t0, t1).fitness(model)
    rows = eng.stats.rows_scanned
    grown = _append_noise(mmlog, 400)
    r2 = Q.log(grown).using(eng).window(t0, t1).fitness(model)
    assert r2.from_cache and eng.stats.delta_free_hits == 1
    assert eng.stats.rows_scanned == rows
    np.testing.assert_array_equal(
        r1.value.trace_fitness, r2.value.trace_fitness
    )


# ---------------------------------------------------------------------------
# engine planning / caching / stats
# ---------------------------------------------------------------------------


def test_fitness_cache_hit_and_model_memo(mmlog):
    eng = QueryEngine()
    r1 = Q.log(mmlog).using(eng).fitness()
    assert not r1.from_cache
    r2 = Q.log(mmlog).using(eng).fitness()
    assert r2.from_cache
    # sliding windows share the memoized default model (one discovery)
    assert len(eng._model_memo) == 1
    ts = np.asarray(mmlog.time)
    for q in (0.3, 0.6):
        Q.log(mmlog).using(eng).window(0.0, float(np.quantile(ts, q))).fitness()
    assert len(eng._model_memo) == 1
    assert eng.stats.conformance_queries == 4


def test_conformance_backend_validation(mmlog):
    with pytest.raises(QueryPlanError):
        Q.log(mmlog).using(QueryEngine()).fitness(None, backend="pallas")
    repo = repository_from_memmap(mmlog)
    with pytest.raises(QueryPlanError):
        Q.log(repo).using(QueryEngine()).fitness(None, backend="streaming")


def test_out_of_core_guards(mmlog):
    eng = QueryEngine(memory_budget_events=100)
    model = ModelSpec(activities=("act_000",), edges=(), starts=(), ends=())
    # fitness streams; numpy/graph would materialize → rejected
    r = Q.log(mmlog).using(eng).fitness(model)
    assert r.physical.backend == "streaming"
    with pytest.raises(QueryPlanError):
        Q.log(mmlog).using(eng).fitness(model, backend="numpy")
    with pytest.raises(QueryPlanError):
        Q.log(mmlog).using(eng).fitness(model, backend="graph")
    # alignments need the variant table → budget-gated
    with pytest.raises(QueryPlanError):
        Q.log(mmlog).using(eng).alignments(model)


def test_graph_auto_routing_after_crossover(mmlog):
    eng = QueryEngine(graph_crossover=2)
    model = _discover(repository_from_memmap(mmlog), min_count=40)
    ts = np.asarray(mmlog.time)
    windows = [(0.0, float(np.quantile(ts, q))) for q in (0.2, 0.4, 0.6)]
    backends = []
    for t0, t1 in windows:
        r = Q.log(mmlog).using(eng).window(t0, t1).fitness(model)
        backends.append(r.physical.backend)
    assert backends[0] != "graph"  # below the crossover
    assert backends[-1] == "graph"  # amortized: replay from stored tables
    assert eng.stats.graph_queries >= 1


# ---------------------------------------------------------------------------
# alignments
# ---------------------------------------------------------------------------


def test_alignment_hand_computed_costs():
    spec = ModelSpec(
        activities=("a", "b", "c"), edges=(("a", "b"), ("b", "c")),
        starts=("a",), ends=("c",),
    )
    repo = EventRepository.from_traces(
        [["a", "b", "c"], ["a", "c"], ["a", "x", "c"], ["b"]],
        activity_vocab=["a", "b", "c", "x"],
    )
    res = align_repository(repo, spec)
    # t1: perfect; t2: one model move (b); t3: skip x + model move;
    # t4: model move a to sync b, then model move c to finish
    np.testing.assert_array_equal(res.trace_cost, [0, 1, 2, 2])
    assert res.empty_cost == 3  # START→a→b→c→END
    np.testing.assert_allclose(
        res.trace_fitness, [1.0, 1 - 1 / 5, 1 - 2 / 6, 1 - 2 / 4]
    )
    assert res.perfectly_fitting == 1
    assert res.deviating_edges == {("a", "x"): 1, ("x", "c"): 1, ("a", "c"): 1}


def test_alignment_model_path_through_unobserved_activity():
    """D routes through model activities the log never executes — the DP
    state space is the model ∪ log universe, not just the log vocab."""
    spec = ModelSpec(
        activities=("a", "m", "z"), edges=(("a", "m"), ("m", "z")),
        starts=("a",), ends=("z",),
    )
    repo = EventRepository.from_traces([["a", "z"]], activity_vocab=["a", "z"])
    res = align_repository(repo, spec)
    # sync a, model-move m, sync z — cost 1 (not unalignable)
    np.testing.assert_array_equal(res.trace_cost, [1])
    assert res.empty_cost == 3  # START→a→m→z→END executes 3 activities


def test_alignment_unalignable_model():
    spec = ModelSpec(activities=("a",), edges=(), starts=(), ends=())
    repo = EventRepository.from_traces([["a", "a"]])
    res = align_repository(repo, spec)
    assert res.empty_cost == -1
    np.testing.assert_array_equal(res.trace_cost, [2])  # all log moves
    assert res.trace_fitness[0] == 0.0


def test_align_dp_pallas_interpret_matches_numpy():
    rng = np.random.default_rng(17)
    for _ in range(4):
        a = int(rng.integers(3, 14))
        names = [f"a{i}" for i in range(a)]
        edges = tuple(
            (names[i], names[j])
            for i in range(a) for j in range(a) if rng.random() < 0.3
        )
        spec = ModelSpec(
            activities=tuple(names), edges=edges,
            starts=tuple(rng.choice(names, 2)),
            ends=tuple(rng.choice(names, 2)),
        )
        m, d0, endc = alignment_cost_tables(spec, names)
        v, l = int(rng.integers(1, 50)), int(rng.integers(1, 40))
        seqs = rng.integers(0, a, (v, l)).astype(np.int32)
        lens = rng.integers(1, l + 1, v).astype(np.int32)
        c_np = align_dp(seqs, lens, m, d0, endc, backend="numpy")
        c_pl = align_dp(
            seqs, lens, m, d0, endc, backend="pallas", interpret=True
        )
        np.testing.assert_array_equal(c_np, c_pl)


def test_alignments_through_engine_match_direct(mmlog):
    repo = repository_from_memmap(mmlog)
    model = _discover(repo, min_count=60, min_dependency=0.3)
    want = align_repository(repo, model)
    for backend in ("numpy", "graph"):
        res = Q.log(mmlog).using(QueryEngine()).alignments(
            model, backend=backend
        )
        np.testing.assert_array_equal(res.value.trace_cost, want.trace_cost)
        np.testing.assert_array_equal(
            res.value.trace_fitness, want.trace_fitness
        )
        assert res.value.deviating_edges == want.deviating_edges


# ---------------------------------------------------------------------------
# unions + compare
# ---------------------------------------------------------------------------


def test_union_fitness_concatenates_branches(mmlog, tmp_path):
    other = generate_memmap_log(
        str(tmp_path / "mm2"), 8_000,
        ProcessSpec(num_activities=9, seed=21), seed=21, batch_traces=300,
    )
    repo_a = repository_from_memmap(mmlog)
    repo_b = repository_from_memmap(other)
    model = _discover(repo_a, min_count=40, min_dependency=0.3)
    res = Q.logs((mmlog, "a"), (other, "b")).using(QueryEngine()).fitness(
        model
    )
    fa = replay_fitness(repo_a, model)
    fb = replay_fitness(repo_b, model)
    np.testing.assert_array_equal(
        res.value.trace_fitness,
        np.concatenate([fa.trace_fitness, fb.trace_fitness]),
    )
    want_census = dict(fa.deviating_edges)
    for k, v in fb.deviating_edges.items():
        want_census[k] = want_census.get(k, 0) + v
    assert res.value.deviating_edges == want_census


def test_union_default_model_is_reference_branch(mmlog, tmp_path):
    other = generate_memmap_log(
        str(tmp_path / "mm3"), 6_000,
        ProcessSpec(num_activities=9, seed=23), seed=23, batch_traces=300,
    )
    repo_a = repository_from_memmap(mmlog)
    repo_b = repository_from_memmap(other)
    res = Q.logs((mmlog, "a"), (other, "b")).using(QueryEngine()).fitness()
    model = _discover(repo_a, min_dependency=0.5)
    fa = replay_fitness(repo_a, model)
    fb = replay_fitness(repo_b, model)
    np.testing.assert_array_equal(
        res.value.trace_fitness,
        np.concatenate([fa.trace_fitness, fb.trace_fitness]),
    )


def test_union_fitness_append_is_suffix_only(mmlog, tmp_path):
    other = generate_memmap_log(
        str(tmp_path / "mm4"), 6_000,
        ProcessSpec(num_activities=9, seed=25), seed=25, batch_traces=300,
    )
    model = _discover(repository_from_memmap(mmlog), min_count=40)
    eng = QueryEngine(memory_budget_events=1_000, replay_crossover=1_000)
    Q.logs((mmlog, "a"), (other, "b")).using(eng).fitness(model)
    rows = eng.stats.rows_scanned
    grown = _append_noise(other, 300, seed=25)
    r2 = Q.logs((mmlog, "a"), (grown, "b")).using(eng).fitness(model)
    # branch "a" is a cache hit, branch "b" delta-resumes its suffix
    assert eng.stats.rows_scanned - rows == 300
    assert eng.stats.delta_hits == 1
    oracle = np.concatenate([
        replay_fitness(repository_from_memmap(mmlog), model).trace_fitness,
        replay_fitness(repository_from_memmap(grown), model).trace_fitness,
    ])
    np.testing.assert_array_equal(r2.value.trace_fitness, oracle)


# ---------------------------------------------------------------------------
# serving + policy
# ---------------------------------------------------------------------------


def test_service_fitness_census_floor():
    from repro.core.views import AccessPolicy
    from repro.serve.query_service import QueryService

    svc = QueryService()
    repo = EventRepository.from_traces(
        [["a", "b", "c"]] * 40 + [["a", "c", "b"]] * 2
    )
    svc.register("bpi", repo, AccessPolicy(min_group_count=5))
    svc.register("open", repo)
    out = svc.query({"log": "bpi", "sink": "fitness"})
    assert out["deviations"] == []  # counts of 2 fall below the floor of 5
    assert out["total_traces"] == 42
    raw = svc.query({"log": "open", "sink": "fitness"})
    # the self-discovered model admits a→c (dependency 2/3 ≥ 0.5); the
    # reversed c→b flow is the deviation the census reports un-floored
    assert {tuple(d["edge"]) for d in raw["deviations"]} == {("c", "b")}


def test_service_cross_log_model_and_policy_combination():
    from repro.core.views import AccessDenied, AccessPolicy, ActivityView
    from repro.serve.query_service import QueryService

    svc = QueryService()
    main = EventRepository.from_traces([["a", "b"], ["b", "a"]])
    ref = EventRepository.from_traces([["a", "b"]] * 5)
    svc.register("main", main)
    svc.register("ref", ref)
    out = svc.query({"log": "main", "sink": "fitness", "model_of": "ref"})
    assert 0.0 < out["fitness"] < 1.0
    ali = svc.query({"log": "main", "sink": "alignments", "model_of": "ref"})
    assert ali["empty_cost"] == 2  # START→a→b→END executes two activities

    # a view-protected reference cannot be combined with a bare log
    svc.register(
        "guarded", ref,
        AccessPolicy(view=ActivityView(mapping={"a": "g", "b": "g"})),
    )
    with pytest.raises(AccessDenied):
        svc.query({"log": "main", "sink": "fitness", "model_of": "guarded"})


def test_model_memo_never_aliases_viewed_and_raw_models():
    """Regression: a raw resolution (compare's whole-log signal) and a
    view-governed resolution (serve model_of under a view policy) on the
    same source must occupy distinct memo entries — sharing one would let
    a tenant replay against (or warm the memo with) a model at a
    resolution their policy forbids."""
    from repro.core.views import AccessPolicy, ActivityView
    from repro.serve.query_service import QueryService

    ref = EventRepository.from_traces([["a", "b"]] * 5)
    main = EventRepository.from_traces([["a", "b"], ["b", "a"]])
    view = ActivityView(mapping={"a": "g", "b": "g"})

    svc = QueryService()
    svc.register("ref", ref)
    svc.register("main", main)
    # 1) raw resolution first (fills the memo with the un-viewed model)
    raw = svc.query({"logs": ["main", "ref"], "sink": "compare"})
    # 2) the same reference under a view policy must see the group model
    svc.register("guardedmain", main, AccessPolicy(view=view))
    svc.register("guardedref", ref, AccessPolicy(view=view))
    out = svc.query({
        "log": "guardedmain", "sink": "fitness", "model_of": "guardedref",
    })
    # under the coarsening view both logs collapse to g→g walks: the
    # group-level model fits everything; the raw model would not
    assert out["fitness"] == 1.0
    assert raw["fitness"]["main"] < 1.0
    assert len(svc.engine._model_memo) == 2  # distinct entries, no alias


# ---------------------------------------------------------------------------
# calibration (satellite)
# ---------------------------------------------------------------------------


def test_replay_crossover_calibration(tmp_path, monkeypatch):
    monkeypatch.delenv("GRAPHPM_BENCH_CONFORMANCE", raising=False)
    bench = tmp_path / "BENCH_conformance.json"
    bench.write_text('{"calibration": {"replay_streaming_crossover": 999}}')
    cal = load_calibration(conformance_path=str(bench))
    assert cal["replay_streaming_crossover"] == 1 << 18  # clamped floor
    bench.write_text(
        '{"calibration": {"replay_streaming_crossover": 1048576}}'
    )
    cal = load_calibration(conformance_path=str(bench))
    assert cal["replay_streaming_crossover"] == 1 << 20
    # explicit engine arg wins over any calibration record
    monkeypatch.setenv("GRAPHPM_BENCH_CONFORMANCE", str(bench))
    eng = QueryEngine(replay_crossover=123)
    assert eng.replay_crossover == 123
    eng2 = QueryEngine()
    assert eng2.replay_crossover == 1 << 20
