"""Fault tolerance: checkpoint/restart golden test, failure injection,
straggler detection, loss-goes-down, data determinism."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import TrainHParams
from repro.data.lm_data import TokenPipeline
from repro.train import Trainer, TrainerError


def _tiny_cfg():
    cfg = get_config("starcoder2-3b").reduced()
    return dataclasses.replace(cfg, vocab_size=64, loss_chunk=8)


def _pipeline(cfg, batch=2, seq=16, seed=3):
    return TokenPipeline(
        vocab_size=cfg.vocab_size, batch=batch, seq_len=seq, seed=seed,
        branching=4,
    )


HP = TrainHParams(learning_rate=3e-3, warmup_steps=2, total_steps=200,
                  grad_clip=1.0)


def test_data_pipeline_deterministic():
    cfg = _tiny_cfg()
    p = _pipeline(cfg)
    b1, b2 = p(7), p(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_loss_decreases_on_markov_language(tmp_path):
    cfg = _tiny_cfg()
    tr = Trainer(cfg, HP, _pipeline(cfg), str(tmp_path / "ck"), ckpt_every=50,
                 q_chunk=16)
    out = tr.run(30)
    first = np.mean(out["history"][:5])
    last = np.mean(out["history"][-5:])
    assert last < first - 0.1, (first, last)
    # and below the uniform floor ln(V), heading toward the bigram entropy
    assert last < np.log(cfg.vocab_size)


def test_restart_golden_equivalence(tmp_path):
    """Crash at step 7, restart from the step-5 checkpoint → final history
    tail and loss identical to an uninterrupted run (deterministic data +
    synchronous state)."""
    cfg = _tiny_cfg()

    ref = Trainer(cfg, HP, _pipeline(cfg), str(tmp_path / "ref"),
                  ckpt_every=5, q_chunk=16)
    ref_out = ref.run(10)

    crashed = {"done": False}

    def injector(step):
        if step == 7 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    tr = Trainer(cfg, HP, _pipeline(cfg), str(tmp_path / "ft"),
                 ckpt_every=5, q_chunk=16, failure_injector=injector)
    out = tr.run(10)
    assert crashed["done"]
    assert out["final_step"] == 10
    # the last 3 losses (post-restart, steps 7..9) must match exactly
    np.testing.assert_allclose(
        out["history"][-3:], ref_out["history"][-3:], rtol=0, atol=0
    )


def test_restart_resumes_from_checkpoint_not_scratch(tmp_path):
    cfg = _tiny_cfg()
    calls = []

    def injector(step):
        calls.append(step)
        if step == 6 and calls.count(6) == 1:
            raise RuntimeError("boom")

    tr = Trainer(cfg, HP, _pipeline(cfg), str(tmp_path / "ck"),
                 ckpt_every=5, q_chunk=16, failure_injector=injector)
    out = tr.run(8)
    # restarted from 5 (checkpoint), not 0: step 6 ran twice, step 0 once
    assert calls.count(6) == 2
    assert calls.count(0) == 1
    assert out["final_step"] == 8


def test_gives_up_after_max_retries(tmp_path):
    cfg = _tiny_cfg()

    def always_fail(step):
        raise RuntimeError("dead node")

    tr = Trainer(cfg, HP, _pipeline(cfg), str(tmp_path / "ck"),
                 ckpt_every=5, q_chunk=16, failure_injector=always_fail,
                 max_retries=2)
    with pytest.raises(TrainerError):
        tr.run(5)


def test_telemetry_mined_as_process(tmp_path):
    """The trainer's event log IS a GraphPM event repository: discover the
    step process and check its DFG is the expected chain."""
    from repro.core import dfg_from_repository

    cfg = _tiny_cfg()
    tr = Trainer(cfg, HP, _pipeline(cfg), str(tmp_path / "ck"),
                 ckpt_every=100, q_chunk=16)
    tr.run(6)
    repo = tr.collector.to_repository()
    psi = dfg_from_repository(repo)
    names = repo.activity_names
    li, ti, gi = (names.index(x) for x in ("load_batch", "train_step", "log"))
    assert psi[li, ti] == 6  # load → train, every step
    assert psi[ti, gi] == 6  # train → log, every step
