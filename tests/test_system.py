"""End-to-end behaviour: the full paper pipeline (Fig. 1) — load log →
compute DFG in-store → discover model — plus the privacy path."""

import numpy as np

from repro.core import (
    AccessPolicy,
    ActivityView,
    AnalystSession,
    check_columnar,
    dfg_from_repository,
    discover_dependency_graph,
    filter_dfg,
    footprint,
    footprint_conformance,
)
from repro.data import ProcessSpec, generate_repository


def test_end_to_end_discovery_pipeline():
    # 1. load the log (Fig. 1 step 1)
    repo = generate_repository(1000, ProcessSpec(num_activities=20, seed=42))
    assert check_columnar(repo).ok

    # 2. DFG in-store (Fig. 1 step 2) — two backends must agree
    psi = dfg_from_repository(repo, backend="scatter")
    psi2 = dfg_from_repository(repo, backend="pallas")
    np.testing.assert_array_equal(psi, psi2)

    # 3. discover the model (Fig. 1 step 3)
    starts, ends = repo.trace_boundaries()
    model = discover_dependency_graph(
        filter_dfg(psi, min_count=3), repo.activity_names, starts, ends,
        min_count=3, min_dependency=0.3,
    )
    assert len(model.edges) > 0
    assert model.start_activities and model.end_activities


def test_end_to_end_privacy_pipeline():
    """Analyst computes a coarse process model without ever seeing events."""
    repo = generate_repository(500, ProcessSpec(num_activities=12, seed=7))
    view = ActivityView(
        mapping={f"act_{i:03d}": f"dept_{i % 3}" for i in range(12)}
    )
    sess = AnalystSession(repo, AccessPolicy(aggregate_only=True, view=view))
    psi, names = sess.dfg()
    assert names == ["dept_0", "dept_1", "dept_2"]
    assert psi.sum() > 0


def test_dicing_consistency_full_vs_windows():
    """Union of disjoint window dices ≤ full DFG; windows covering the whole
    horizon with paper semantics lose only boundary-crossing pairs."""
    repo = generate_repository(300, ProcessSpec(num_activities=10, seed=13))
    full = dfg_from_repository(repo)
    tmin, tmax = repo.event_time.min(), repo.event_time.max() + 1.0
    mid = (tmin + tmax) / 2
    w1 = dfg_from_repository(repo, time_window=(tmin, mid))
    w2 = dfg_from_repository(repo, time_window=(mid, tmax))
    assert ((w1 + w2) <= full).all()
    lost = full.sum() - (w1 + w2).sum()
    assert lost >= 0  # exactly the pairs straddling `mid`


def test_conformance_between_time_slices():
    """Footprint conformance across halves of a stationary process is high."""
    repo = generate_repository(2000, ProcessSpec(num_activities=10, seed=3))
    tmin, tmax = repo.event_time.min(), repo.event_time.max() + 1.0
    mid = (tmin + tmax) / 2
    f1 = footprint(dfg_from_repository(repo, time_window=(tmin, mid)))
    f2 = footprint(dfg_from_repository(repo, time_window=(mid, tmax)))
    assert footprint_conformance(f1, f2) > 0.8
