"""Definition 2 soundness — positive, negative, and property-based."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    EventRepository,
    GraphRepo,
    check_columnar,
    check_graph,
    paper_example_repo,
)


def _sound_graph():
    return paper_example_repo().to_graph()


def test_paper_example_is_sound():
    assert check_graph(_sound_graph()).ok
    assert check_columnar(paper_example_repo()).ok


def test_trace_in_two_logs_violates_s1():
    g = _sound_graph()
    g.logs.add("log:l2")
    g.relations.add(("log:l2", "trace:t1"))
    rep = check_graph(g)
    assert not rep.ok and any("S1" in v for v in rep.violations)


def test_event_in_two_traces_violates_s2():
    g = _sound_graph()
    g.relations.add(("trace:t2", "e1"))
    rep = check_graph(g)
    assert not rep.ok and any("S2" in v for v in rep.violations)


def test_event_two_outgoing_flows_violates_s4():
    g = _sound_graph()
    g.relations.add(("e1", "e3"))  # e1 already flows to e2
    rep = check_graph(g)
    assert not rep.ok and any("S4" in v for v in rep.violations)


def test_event_two_incoming_flows_violates_s3():
    g = _sound_graph()
    g.relations.add(("e1", "e3"))
    rep = check_graph(g)
    assert any("S3" in v or "S4" in v for v in rep.violations)


def test_event_without_activity_violates_s5():
    g = _sound_graph()
    g.relations.discard(("e1", "act:a1"))
    rep = check_graph(g)
    assert not rep.ok and any("S5" in v for v in rep.violations)


def test_event_two_activities_violates_s5():
    g = _sound_graph()
    g.relations.add(("e1", "act:a2"))
    rep = check_graph(g)
    assert not rep.ok and any("S5" in v for v in rep.violations)


def test_columnar_non_contiguous_traces_detected():
    repo = EventRepository(
        event_activity=np.array([0, 1, 0], dtype=np.int32),
        event_trace=np.array([0, 1, 0], dtype=np.int32),  # trace 0 split!
        event_time=np.array([0.0, 1.0, 2.0]),
        trace_log=np.zeros(2, dtype=np.int32),
        activity_names=["a", "b"],
        trace_names=["t1", "t2"],
        log_names=["l1"],
    )
    rep = check_columnar(repo)
    assert not rep.ok and any("S3/S4" in v for v in rep.violations)


def test_columnar_time_order_detected():
    repo = EventRepository(
        event_activity=np.array([0, 1], dtype=np.int32),
        event_trace=np.array([0, 0], dtype=np.int32),
        event_time=np.array([2.0, 1.0]),  # decreasing
        trace_log=np.zeros(1, dtype=np.int32),
        activity_names=["a", "b"],
        trace_names=["t1"],
        log_names=["l1"],
    )
    rep = check_columnar(repo)
    assert not rep.ok


# -- property: every repository built through the public constructor is sound
traces_strategy = st.lists(
    st.lists(st.sampled_from(["a", "b", "c", "d", "e"]), min_size=1, max_size=12),
    min_size=0,
    max_size=12,
)


@settings(max_examples=60, deadline=None)
@given(traces=traces_strategy)
def test_from_traces_always_sound(traces):
    repo = EventRepository.from_traces(traces)
    assert check_columnar(repo).ok
    g = repo.to_graph()
    assert check_graph(g).ok
    # graph roundtrip preserves DFG
    from repro.core import dfg_from_repository

    back = g.to_columnar()
    np.testing.assert_array_equal(
        dfg_from_repository(repo),
        dfg_from_repository(back),
    )


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=40),
    k=st.integers(min_value=1, max_value=5),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_from_event_table_sound_for_random_tables(n, k, seed):
    rng = np.random.default_rng(seed)
    cases = [f"c{int(x)}" for x in rng.integers(0, k, size=n)]
    acts = [f"a{int(x)}" for x in rng.integers(0, 4, size=n)]
    times = rng.uniform(0, 100, size=n)
    repo = EventRepository.from_event_table(cases, acts, times)
    assert check_columnar(repo).ok
