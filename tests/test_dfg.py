"""Algorithm 1 correctness: paper worked example + backend equivalence."""

import numpy as np
import pytest

from repro.core import (
    dfg,
    dfg_algorithm1,
    dfg_from_repository,
    dfg_numpy,
    paper_example_repo,
)
from repro.data import ProcessSpec, generate_repository

PAPER_TABLE_1 = np.array(
    [
        [0, 1, 0, 0],
        [0, 0, 2, 0],
        [0, 0, 0, 1],
        [0, 0, 0, 0],
    ],
    dtype=np.int64,
)


def test_paper_example_table1():
    """Table 1 of the paper, computed three independent ways."""
    repo = paper_example_repo()
    assert repo.activity_names == ["a1", "a2", "a3", "a4"]
    # columnar / jnp path
    np.testing.assert_array_equal(dfg_from_repository(repo), PAPER_TABLE_1)
    # literal Algorithm 1 on the explicit graph
    psi, acts = dfg_algorithm1(repo.to_graph())
    assert acts == ["act:a1", "act:a2", "act:a3", "act:a4"]
    np.testing.assert_array_equal(psi, PAPER_TABLE_1)
    # numpy pair counting
    src, dst, valid = repo.df_pairs()
    np.testing.assert_array_equal(
        dfg_numpy(src, dst, valid, 4), PAPER_TABLE_1
    )


def test_paper_example_preset_operator():
    """•a2 = {e2, e4} per the paper's §3.2 walkthrough."""
    repo = paper_example_repo()
    g = repo.to_graph()
    assert g.preset("act:a2") == {"e2", "e4"}
    assert g.preset("act:a3") == {"e3", "e5"}


@pytest.mark.parametrize("backend", ["scatter", "onehot", "pallas"])
def test_backends_agree_random(backend):
    repo = generate_repository(200, ProcessSpec(num_activities=17, seed=3))
    expected = dfg_from_repository(repo, backend="scatter")
    got = dfg_from_repository(repo, backend=backend)
    np.testing.assert_array_equal(got, expected)


@pytest.mark.parametrize("backend", ["scatter", "onehot", "pallas"])
def test_total_counts_match_pairs(backend):
    repo = generate_repository(100, ProcessSpec(num_activities=9, seed=7))
    psi = dfg_from_repository(repo, backend=backend)
    _, _, valid = repo.df_pairs()
    assert psi.sum() == valid.sum()
    # row/col sums bounded by activity occurrence counts
    counts = np.bincount(repo.event_activity, minlength=9)
    assert (psi.sum(axis=1) <= counts).all()
    assert (psi.sum(axis=0) <= counts).all()


def test_empty_and_singleton_repos():
    from repro.core import EventRepository

    empty = EventRepository.from_traces([])
    assert dfg_from_repository(empty).shape == (0, 0)
    single = EventRepository.from_traces([["a"]])
    np.testing.assert_array_equal(
        dfg_from_repository(single), np.zeros((1, 1), dtype=np.int64)
    )


def test_single_trace_chain():
    from repro.core import EventRepository

    repo = EventRepository.from_traces([["a", "b", "a", "b"]])
    psi = dfg_from_repository(repo)
    np.testing.assert_array_equal(psi, [[0, 2], [1, 0]])


def test_no_cross_trace_pairs():
    from repro.core import EventRepository

    repo = EventRepository.from_traces([["a", "b"], ["c", "d"]])
    psi = dfg_from_repository(repo)
    # b->c must NOT be counted
    names = repo.activity_names
    assert psi[names.index("b"), names.index("c")] == 0
    assert psi.sum() == 2
