"""Dicing (Experiment 2 semantics) and access-control views (privacy)."""

import numpy as np
import pytest

from repro.core import (
    AccessPolicy,
    ActivityView,
    AnalystSession,
    EventRepository,
    HIDDEN,
    dfg_from_repository,
    dice_repository,
    pair_mask_for_window,
)
from repro.core.views import AccessDenied
from repro.data import ProcessSpec, generate_repository


def test_window_mask_paper_semantics():
    repo = EventRepository.from_event_table(
        ["c", "c", "c", "c"], ["a", "b", "c", "d"], [0.0, 1.0, 2.0, 3.0]
    )
    # window [1, 3): only events b (t=1) and c (t=2) inside
    psi = dfg_from_repository(repo, time_window=(1.0, 3.0))
    names = repo.activity_names
    assert psi.sum() == 1
    assert psi[names.index("b"), names.index("c")] == 1


def test_paper_vs_pm4py_semantics_agree_for_contiguous_windows():
    """For time-sorted traces, a contiguous window keeps a contiguous
    subsequence of every trace → re-linking adds nothing."""
    repo = generate_repository(300, ProcessSpec(num_activities=12, seed=5))
    t0 = float(np.quantile(repo.event_time, 0.2))
    t1 = float(np.quantile(repo.event_time, 0.6))
    paper = dfg_from_repository(repo, time_window=(t0, t1))
    diced = dice_repository(repo, time_window=(t0, t1))
    pm4py_style = dfg_from_repository(diced)
    np.testing.assert_array_equal(paper, pm4py_style)


def test_dice_repository_stays_sound():
    from repro.core import check_columnar

    repo = generate_repository(100, ProcessSpec(num_activities=8, seed=2))
    t0 = float(np.quantile(repo.event_time, 0.3))
    t1 = float(np.quantile(repo.event_time, 0.8))
    diced = dice_repository(repo, time_window=(t0, t1))
    assert check_columnar(diced).ok
    assert diced.num_events <= repo.num_events


def test_empty_window_gives_zero_dfg():
    repo = generate_repository(50, ProcessSpec(num_activities=6, seed=1))
    psi = dfg_from_repository(repo, time_window=(-10.0, -5.0))
    assert psi.sum() == 0


def test_activity_dice():
    repo = EventRepository.from_traces([["a", "b", "c", "a"]])
    diced = dice_repository(repo, activities=["a", "c"])
    # re-linking semantics: a->c (b removed), c->a
    psi = dfg_from_repository(diced)
    names = diced.activity_names
    assert psi[names.index("a"), names.index("c")] == 1
    assert psi[names.index("c"), names.index("a")] == 1


# -- views / privacy ---------------------------------------------------------


def test_activity_view_grouping_preserves_mass():
    """The postal-code example: grouped DFG sums equal ungrouped sums
    (restricted to visible groups)."""
    repo = EventRepository.from_traces(
        [["reg_a", "reg_b", "pay_x"], ["reg_a", "pay_y", "pay_x"]]
    )
    view = ActivityView(
        mapping={
            "reg_a": "register", "reg_b": "register",
            "pay_x": "payment", "pay_y": "payment",
        }
    )
    psi = dfg_from_repository(repo)
    grouped = view.apply_to_dfg(psi, repo.activity_names)
    assert grouped.shape == (2, 2)
    assert grouped.sum() == psi.sum()


def test_hidden_activities_are_removed():
    repo = EventRepository.from_traces([["a", "secret", "b"]])
    view = ActivityView(mapping={"a": "a", "b": "b"})  # secret -> HIDDEN
    psi = dfg_from_repository(repo, view=view)
    assert psi.shape == (2, 2)
    # flows through the hidden node are not exposed
    assert psi.sum() == 0


def test_analyst_session_aggregate_only():
    repo = generate_repository(50, ProcessSpec(num_activities=6, seed=9))
    sess = AnalystSession(repo, AccessPolicy(aggregate_only=True))
    psi, names = sess.dfg()
    assert psi.shape == (6, 6)
    with pytest.raises(AccessDenied):
        sess.events()
    # raw repo must not be reachable as a public attribute
    assert not hasattr(sess, "repo")
    assert not any(
        isinstance(getattr(sess, n, None), type(repo))
        for n in dir(sess)
        if not n.startswith("_")
    )


def test_analyst_session_policy_blocks_dicing():
    repo = generate_repository(20, ProcessSpec(num_activities=5, seed=4))
    sess = AnalystSession(
        repo, AccessPolicy(aggregate_only=True, time_windows_allowed=False)
    )
    with pytest.raises(AccessDenied):
        sess.dfg(time_window=(0.0, 1.0))


def test_k_anonymity_floor():
    repo = EventRepository.from_traces([["a", "b"]] * 3 + [["a", "c"]])
    sess = AnalystSession(repo, AccessPolicy(min_group_count=2))
    psi, names = sess.dfg()
    assert psi[names.index("a"), names.index("c")] == 0  # suppressed (count 1)
    assert psi[names.index("a"), names.index("b")] == 3


def test_view_applied_in_session():
    repo = EventRepository.from_traces([["a1", "a2"], ["a1", "a3"]])
    view = ActivityView(mapping={"a1": "g1", "a2": "g2", "a3": "g2"})
    sess = AnalystSession(repo, AccessPolicy(view=view))
    psi, names = sess.dfg()
    assert names == ["g1", "g2"]
    assert psi[0, 1] == 2
    hist, hnames = sess.activity_histogram()
    assert hnames == ["g1", "g2"]
    assert hist.tolist() == [2, 2]
