"""Production-mesh dry-run smoke (subprocess: needs 512 placeholder devices
before jax init; the main test process must keep its single real device)."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_cell_compiles_on_production_mesh(tmp_path, mesh):
    """whisper-tiny × decode_32k: the fastest cell — proves the 16×16 and
    2×16×16 meshes build, shard, lower, and compile end to end."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "whisper-tiny", "--shape", "decode_32k",
            "--mesh", mesh, "--tag", "pytest", "--out", str(tmp_path),
        ],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    tag = "16x16" if mesh == "single" else "2x16x16"
    art = tmp_path / f"whisper-tiny__decode_32k__{tag}__pytest.json"
    d = json.loads(art.read_text())
    assert d["chips"] == (256 if mesh == "single" else 512)
    assert d["fits_hbm"] is True
    assert d["unknown_trip_whiles"] == 0
    assert d["dominant_term"] in ("compute", "memory", "collective")
