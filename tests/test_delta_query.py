"""Append-aware delta query plans: prefix-preserving fingerprints, the
``delta`` physical path (resume cached streaming state over just the
appended suffix), the free rewrite (window inside the old range ⇒ cached
result stays valid), and the satellite cache-correctness fixes."""

import os
import shutil
import time

import numpy as np
import pytest

from repro.core import MemmapLog, streaming_dfg
from repro.core.dfg import dfg_numpy
from repro.data import ProcessSpec, generate_memmap_log, generate_repository
from repro.query import (
    EMPTY_WINDOW,
    DFGSink,
    Q,
    QueryEngine,
    QueryPlanError,
    canonicalize,
    fingerprint,
    fingerprint_repository,
    parse_memmap_fingerprint,
    prefix_digest,
)
from repro.query.ast import Window
from repro.query.execute import repository_from_memmap

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hyp_st

    HAVE_HYPOTHESIS = True
except ImportError:  # tier-1 must not depend on hypothesis
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _write_log(path, act, case, times, num_activities):
    act = np.asarray(act, np.int32)
    case = np.asarray(case, np.int32)
    times = np.asarray(times, np.float64)
    w = MemmapLog.create(
        str(path), act.shape[0], num_activities,
        int(case.max()) + 1 if case.size else 1, chunk_rows=64,
    )
    w.append(act, case, times)
    return w.close()


def _oracle_psi(act, case, times, num_activities):
    """Algorithm 1 on the flat stream: stable (case, time) sort, count
    consecutive same-case pairs."""
    act = np.asarray(act)
    case = np.asarray(case)
    times = np.asarray(times)
    n = act.shape[0]
    order = np.lexsort((np.arange(n), times, case))
    a, c = act[order], case[order]
    psi = np.zeros((num_activities, num_activities), np.int64)
    for i in range(1, n):
        if c[i] == c[i - 1]:
            psi[a[i - 1], a[i]] += 1
    return psi


def _interleaved_stream(rng, n_events, n_cases, n_acts, t0=0.0):
    act = rng.integers(0, n_acts, n_events).astype(np.int32)
    case = rng.integers(0, n_cases, n_events).astype(np.int32)
    times = t0 + np.sort(rng.uniform(0.0, 1000.0, n_events))
    return act, case, times


@pytest.fixture(scope="module")
def base_log(tmp_path_factory):
    path = tmp_path_factory.mktemp("delta") / "base"
    return generate_memmap_log(
        str(path), 20_000, ProcessSpec(num_activities=9, seed=41), seed=41,
        batch_traces=300,
    )


@pytest.fixture()
def log_copy(base_log, tmp_path):
    """Fresh on-disk copy — append tests mutate the files."""
    path = str(tmp_path / "log")
    shutil.copytree(base_log.path, path)
    return MemmapLog.open(path)


def _append_tail(log, n, seed=0, reuse_cases=True, new_activity=False):
    """Time-ordered suffix reusing existing case ids (so pairs straddle the
    append boundary)."""
    rng = np.random.default_rng(seed)
    a_hi = log.num_activities + (1 if new_activity else 0)
    act = rng.integers(0, a_hi, n).astype(np.int32)
    if new_activity:
        act[0] = log.num_activities  # guarantee the vocabulary grows
    pool = log.num_traces if reuse_cases else log.num_traces + n
    case = rng.integers(0, pool, n).astype(np.int32)
    times = float(log.time[-1]) + np.sort(rng.uniform(0.0, 500.0, n))
    return log.append(act, case, times)


# ---------------------------------------------------------------------------
# prefix-preserving fingerprints
# ---------------------------------------------------------------------------


def test_fingerprint_is_prefix_preserving(log_copy):
    fp_old = fingerprint(log_copy)
    old = parse_memmap_fingerprint(fp_old)
    assert old.num_events == log_copy.num_events
    assert old.prefix == prefix_digest(log_copy)

    grown = _append_tail(log_copy, 123, seed=1)
    fp_new = fingerprint(grown)
    assert fp_new != fp_old
    # the proof: the old prefix digest is recomputable on the grown log
    assert prefix_digest(grown, old.num_events) == old.prefix


def test_prefix_digest_detects_rewrite(log_copy):
    old = parse_memmap_fingerprint(fingerprint(log_copy))
    with open(os.path.join(log_copy.path, "activity.i32"), "r+b") as f:
        f.seek(0)  # head rows are always in the sample
        raw = np.frombuffer(f.read(4), np.int32)
        f.seek(0)
        f.write(((raw + 1) % log_copy.num_activities).astype(np.int32).tobytes())
    edited = MemmapLog.open(log_copy.path)
    assert prefix_digest(edited, old.num_events) != old.prefix


def test_fingerprint_repository_hashes_trace_names():
    repo = generate_repository(50, ProcessSpec(num_activities=5, seed=3))
    renamed = type(repo)(
        event_activity=repo.event_activity,
        event_trace=repo.event_trace,
        event_time=repo.event_time,
        trace_log=repo.trace_log,
        activity_names=repo.activity_names,
        trace_names=[f"other_{n}" for n in repo.trace_names],
        log_names=repo.log_names,
    )
    assert fingerprint_repository(repo) != fingerprint_repository(renamed)


# ---------------------------------------------------------------------------
# the delta physical path
# ---------------------------------------------------------------------------


def test_delta_scans_only_the_suffix_bit_identical(log_copy):
    eng = QueryEngine(memory_budget_events=0)  # streaming-first
    first = Q.log(log_copy).using(eng).dfg()
    assert first.physical.backend == "streaming"
    base_scanned = eng.stats.rows_scanned
    assert base_scanned == log_copy.num_events

    grown = _append_tail(log_copy, 250, seed=2)
    res = Q.log(grown).using(eng).dfg()

    assert res.physical.backend == "delta"
    assert res.physical.delta_rows == (log_copy.num_events, grown.num_events)
    # the cache-stats proof that only the suffix was scanned
    assert eng.stats.rows_scanned - base_scanned == 250
    assert eng.stats.delta_hits == 1 and not res.from_cache
    np.testing.assert_array_equal(res.value, streaming_dfg(grown))
    # ... and against the Algorithm 1 oracle on the materialized stream
    repo = repository_from_memmap(grown)
    src, dst, valid = repo.df_pairs()
    np.testing.assert_array_equal(
        res.value, dfg_numpy(src, dst, valid, repo.num_activities)
    )
    # the delta result was re-cached: the next run is a plain hit
    again = Q.log(grown).using(eng).dfg()
    assert again.from_cache and eng.stats.delta_hits == 1


def test_delta_links_pairs_straddling_the_boundary(tmp_path):
    """Interleaved cases whose last prefix event pairs with their first
    suffix event — the carried last_by_case state is what counts them."""
    # case 0: 0 .. 2 | 1   case 1: 1 .. 0 | 2   (| = append boundary)
    log = _write_log(
        tmp_path / "log",
        act=[0, 1, 2, 0], case=[0, 1, 0, 1], times=[0.0, 1.0, 2.0, 3.0],
        num_activities=3,
    )
    eng = QueryEngine(memory_budget_events=0)
    Q.log(log).using(eng).dfg()
    grown = log.append(
        np.array([1, 2], np.int32), np.array([0, 1], np.int32),
        np.array([4.0, 5.0]),
    )
    res = Q.log(grown).using(eng).dfg()
    assert res.physical.backend == "delta"
    want = np.zeros((3, 3), np.int64)
    want[0, 2] = 1  # case 0 prefix
    want[1, 0] = 1  # case 1 prefix
    want[2, 1] = 1  # case 0 boundary pair
    want[0, 2] += 1  # case 1 boundary pair
    np.testing.assert_array_equal(res.value, want)


def test_delta_histogram(log_copy):
    eng = QueryEngine(memory_budget_events=0)
    Q.log(log_copy).using(eng).histogram()
    grown = _append_tail(log_copy, 100, seed=3)
    base_scanned = eng.stats.rows_scanned
    res = Q.log(grown).using(eng).histogram()
    assert res.physical.backend == "delta"
    assert eng.stats.rows_scanned - base_scanned == 100
    want = np.zeros(grown.num_activities, np.int64)
    for a, _, _ in grown.iter_chunks():
        want += np.bincount(a, minlength=grown.num_activities)
    np.testing.assert_array_equal(res.value, want)


def test_delta_windowed_open_to_the_right(log_copy):
    """A window whose right edge lies beyond the old data resumes the
    cached state and scans only the in-window part of the suffix."""
    eng = QueryEngine(memory_budget_events=0)
    t0 = float(np.asarray(log_copy.time)[5000])
    t1 = float(log_copy.time[-1]) + 1e9
    Q.log(log_copy).using(eng).window(t0, t1).dfg()
    grown = _append_tail(log_copy, 200, seed=4)
    base_scanned = eng.stats.rows_scanned
    res = Q.log(grown).using(eng).window(t0, t1).dfg()
    assert res.physical.backend == "delta"
    assert eng.stats.rows_scanned - base_scanned == 200
    np.testing.assert_array_equal(
        res.value, streaming_dfg(grown, time_window=(t0, t1))
    )


def test_free_rewrite_window_inside_old_range(log_copy):
    """Append-only change + query window entirely inside the old time range
    ⇒ the cached result is served without any scan."""
    eng = QueryEngine(memory_budget_events=0)
    ts = np.asarray(log_copy.time)
    t0, t1 = float(ts[2000]), float(ts[15000])
    first = Q.log(log_copy).using(eng).window(t0, t1).dfg()
    grown = _append_tail(log_copy, 150, seed=5)
    base_scanned = eng.stats.rows_scanned
    res = Q.log(grown).using(eng).window(t0, t1).dfg()
    assert res.from_cache
    assert eng.stats.delta_free_hits == 1
    assert eng.stats.rows_scanned == base_scanned  # zero rows touched
    np.testing.assert_array_equal(res.value, first.value)
    np.testing.assert_array_equal(
        res.value, streaming_dfg(grown, time_window=(t0, t1))
    )
    # republished under the new fingerprint: the next run is a plain hit
    hits = eng.stats.cache_hits
    assert Q.log(grown).using(eng).window(t0, t1).dfg().from_cache
    assert eng.stats.cache_hits == hits + 1


def test_delta_with_grown_activity_vocabulary(log_copy):
    eng = QueryEngine(memory_budget_events=0)
    Q.log(log_copy).using(eng).dfg()
    grown = _append_tail(log_copy, 80, seed=6, new_activity=True)
    assert grown.num_activities == log_copy.num_activities + 1
    res = Q.log(grown).using(eng).dfg()
    assert res.physical.backend == "delta"
    assert res.value.shape == (grown.num_activities,) * 2
    np.testing.assert_array_equal(res.value, streaming_dfg(grown))


def test_rewritten_prefix_falls_back_to_full_recompute(log_copy):
    eng = QueryEngine(memory_budget_events=0)
    Q.log(log_copy).using(eng).dfg()
    # edit a sampled head row, then grow: not append-only
    with open(os.path.join(log_copy.path, "activity.i32"), "r+b") as f:
        raw = int(np.frombuffer(f.read(4), np.int32)[0])
        f.seek(0)
        f.write(
            np.asarray([(raw + 1) % log_copy.num_activities], np.int32).tobytes()
        )
    edited = _append_tail(MemmapLog.open(log_copy.path), 50, seed=7)
    res = Q.log(edited).using(eng).dfg()
    assert res.physical.backend == "streaming"  # full rescan, no stale reuse
    assert eng.stats.delta_hits == 0 and eng.stats.delta_free_hits == 0
    np.testing.assert_array_equal(res.value, streaming_dfg(edited))


def test_repeated_appends_chain_deltas(log_copy):
    eng = QueryEngine(memory_budget_events=0)
    Q.log(log_copy).using(eng).dfg()
    log = log_copy
    for i in range(3):
        log = _append_tail(log, 60, seed=10 + i)
        res = Q.log(log).using(eng).dfg()
        assert res.physical.backend == "delta"
        assert res.physical.delta_rows == (log.num_events - 60, log.num_events)
    assert eng.stats.delta_hits == 3
    np.testing.assert_array_equal(res.value, streaming_dfg(log))


# ---------------------------------------------------------------------------
# append → run ≡ full recompute (property)
# ---------------------------------------------------------------------------


def _check_append_equals_recompute(tmp_path, seed, n_base, n_app, n_cases, n_acts):
    rng = np.random.default_rng(seed)
    act, case, times = _interleaved_stream(rng, n_base, n_cases, n_acts)
    log = _write_log(tmp_path / f"log{seed}", act, case, times, n_acts)
    eng = QueryEngine(memory_budget_events=0)
    Q.log(log).using(eng).dfg()

    a2, c2, t2 = _interleaved_stream(
        rng, n_app, n_cases, n_acts, t0=float(times[-1])
    )
    grown = log.append(a2, c2, t2)
    res = Q.log(grown).using(eng).dfg()
    assert res.physical.backend == "delta"

    all_act = np.concatenate([act, a2])
    all_case = np.concatenate([case, c2])
    all_t = np.concatenate([times, t2])
    np.testing.assert_array_equal(
        res.value, _oracle_psi(all_act, all_case, all_t, n_acts)
    )
    np.testing.assert_array_equal(res.value, streaming_dfg(grown))


@pytest.mark.parametrize("seed", range(8))
def test_append_then_run_equals_recompute_seeded(tmp_path, seed):
    """Seeded property sweep (runs without hypothesis): random interleaved
    streams + random-size appends are bit-identical to the oracle."""
    rng = np.random.default_rng(1000 + seed)
    _check_append_equals_recompute(
        tmp_path, seed,
        n_base=int(rng.integers(2, 400)),
        n_app=int(rng.integers(1, 200)),
        n_cases=int(rng.integers(1, 12)),
        n_acts=int(rng.integers(2, 9)),
    )


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        seed=hyp_st.integers(0, 2**16),
        n_base=hyp_st.integers(2, 150),
        n_app=hyp_st.integers(1, 80),
        n_cases=hyp_st.integers(1, 8),
        n_acts=hyp_st.integers(2, 6),
    )
    def test_append_then_run_equals_recompute_hypothesis(
        tmp_path_factory, seed, n_base, n_app, n_cases, n_acts
    ):
        tmp = tmp_path_factory.mktemp("hyp")
        _check_append_equals_recompute(
            tmp, seed, n_base=n_base, n_app=n_app,
            n_cases=n_cases, n_acts=n_acts,
        )


# ---------------------------------------------------------------------------
# satellite fixes
# ---------------------------------------------------------------------------


def test_cache_hit_reports_its_own_latency(monkeypatch):
    """A hit's wall_s must be the lookup latency, not the first execution's
    scan time replayed back to the tenant."""
    repo = generate_repository(100, ProcessSpec(num_activities=6, seed=8))
    eng = QueryEngine()
    real = eng._execute

    def slow(*args, **kwargs):
        time.sleep(0.05)
        return real(*args, **kwargs)

    monkeypatch.setattr(eng, "_execute", slow)
    first = Q.log(repo).using(eng).dfg()
    assert not first.from_cache and first.wall_s >= 0.05
    second = Q.log(repo).using(eng).dfg()
    assert second.from_cache
    assert 0.0 < second.wall_s < first.wall_s


def test_repo_memo_is_an_lru_over_multiple_logs(tmp_path, monkeypatch):
    """Two tenants alternating over two in-budget memmap logs must not
    re-materialize on every call (the old memo was a single slot)."""
    import repro.query.execute as ex

    logs = [
        generate_memmap_log(
            str(tmp_path / f"l{i}"), 2_000,
            ProcessSpec(num_activities=7, seed=50 + i), seed=50 + i,
            batch_traces=100,
        )
        for i in range(2)
    ]
    calls = []
    real = ex.repository_from_memmap

    def counting(log, log_name=None):
        calls.append(log.path)
        return real(log, log_name)

    monkeypatch.setattr(ex, "repository_from_memmap", counting)
    eng = QueryEngine()  # in-budget → materialized device path
    for t in (1e5, 2e5, 3e5):
        for log in logs:
            res = Q.log(log).using(eng).window(0.0, float(t)).dfg()
            assert res.physical.materialize
    assert len(calls) == 2  # one load per log, ever


def test_empty_windows_share_one_canonical_plan(log_copy):
    q1 = Q.log(log_copy).window(5.0, 3.0)
    q2 = Q.log(log_copy).window(100.0, 90.0)
    q3 = Q.log(log_copy).window(0.0, 10.0).window(20.0, 30.0)  # empty fusion
    p1, n1 = canonicalize(q1.logical_plan(DFGSink()))
    p2, _ = canonicalize(q2.logical_plan(DFGSink()))
    p3, _ = canonicalize(q3.logical_plan(DFGSink()))
    assert "normalize_empty_window" in n1
    assert p1.key() == p2.key() == p3.key()
    assert [op for op in p1.ops if isinstance(op, Window)] == [EMPTY_WINDOW]

    eng = QueryEngine(memory_budget_events=0)
    r1 = q1.using(eng).dfg()
    assert not r1.value.any()
    assert eng.stats.rows_scanned == 0  # short-circuit: no scan at all
    assert q2.using(eng).dfg().from_cache  # differently phrased, same entry
    r3 = q3.using(eng).histogram()
    assert not r3.value.any() and eng.stats.rows_scanned == 0


def test_empty_window_zeros_on_repository():
    repo = generate_repository(200, ProcessSpec(num_activities=6, seed=9))
    eng = QueryEngine()
    res = Q.log(repo).using(eng).window(9.0, 1.0).dfg()
    assert not res.value.any()
    assert res.value.shape == (repo.num_activities,) * 2
    # invalid activity names still error on the short-circuit path
    with pytest.raises(QueryPlanError):
        Q.log(repo).using(eng).window(9.0, 1.0).activities(["nope"]).dfg()


# ---------------------------------------------------------------------------
# serving: the live append endpoint keeps dashboards warm
# ---------------------------------------------------------------------------


def test_service_append_endpoint(log_copy):
    from repro.serve import QueryService

    eng = QueryEngine(memory_budget_events=0)
    svc = QueryService(engine=eng)
    svc.register("live", log_copy)

    out1 = svc.query({"log": "live", "sink": "dfg"})
    assert not out1["from_cache"]

    rng = np.random.default_rng(11)
    t_last = float(log_copy.time[-1])
    ack = svc.append({
        "log": "live",
        "activity": rng.integers(0, log_copy.num_activities, 40).tolist(),
        "case": rng.integers(0, log_copy.num_traces, 40).tolist(),
        "time": np.sort(t_last + rng.uniform(0, 10, 40)).tolist(),
    })
    assert ack["appended"] == 40
    assert ack["num_events"] == log_copy.num_events + 40

    base_scanned = eng.stats.rows_scanned
    out2 = svc.query({"log": "live", "sink": "dfg"})
    assert eng.stats.delta_hits == 1  # warm: suffix-only scan
    assert eng.stats.rows_scanned - base_scanned == 40
    grown = MemmapLog.open(log_copy.path)
    np.testing.assert_array_equal(
        np.asarray(out2["psi"]), streaming_dfg(grown)
    )
    # wall_s forwarded to tenants is the measured per-request time
    out3 = svc.query({"log": "live", "sink": "dfg"})
    assert out3["from_cache"] and 0.0 < out3["wall_s"] < out1["wall_s"]


def test_service_append_rejects_repository():
    from repro.serve import QueryService

    repo = generate_repository(50, ProcessSpec(num_activities=4, seed=12))
    svc = QueryService()
    svc.register("mem", repo)
    with pytest.raises(QueryPlanError):
        svc.append({"log": "mem", "activity": [0], "case": [0], "time": [0.0]})


def test_service_concurrent_appends_are_serialized(log_copy):
    """Parallel appends to one registered log must not interleave column
    writes or lose batches to a last-meta-writer-wins race."""
    import threading

    from repro.serve import QueryService

    eng = QueryEngine(memory_budget_events=0)
    svc = QueryService(engine=eng)
    svc.register("live", log_copy)
    t_const = float(log_copy.time[-1]) + 100.0  # equal times: any order valid
    errors = []

    def worker(i):
        try:
            svc.append({
                "log": "live",
                "activity": [i % log_copy.num_activities] * 50,
                "case": [i % log_copy.num_traces] * 50,
                "time": [t_const] * 50,
            })
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    final = MemmapLog.open(log_copy.path)
    assert final.num_events == log_copy.num_events + 8 * 50  # nothing lost
    hist = svc.query({"log": "live", "sink": "histogram"})
    assert sum(hist["counts"]) == final.num_events  # columns stayed aligned
