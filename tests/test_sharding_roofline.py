"""Sharding rules + roofline HLO-model units (no big compiles)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_shape
from repro.roofline.hlo_model import HloModel, parse_hlo
from repro.roofline.analyze import parse_collectives
from repro.sharding.spec import make_rules, param_shardings, cache_shardings
from repro.launch.mesh import make_test_mesh


def _mesh():
    return make_test_mesh((1, 1), ("data", "model"))


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_rules_kinds():
    mesh = _mesh()
    train = make_rules(mesh, get_shape("train_4k"))
    assert train.batch_axes == ("data",)
    assert train.fsdp_axes == ("data",)
    dec = make_rules(mesh, get_shape("decode_32k"))
    assert dec.fsdp_axes == ()
    # on the production mesh, batch=1 long decode flips to context parallel
    long = make_rules(FakeMesh(), get_shape("long_500k"))
    assert long.batch_axes == () and long.seq_axes == ("data",)
    # …but on a 1-device test mesh batch=1 divides and stays batch-sharded
    long1 = make_rules(mesh, get_shape("long_500k"))
    assert long1.seq_axes == ()


def test_divisibility_guards():
    mesh = _mesh()
    r = make_rules(mesh, get_shape("train_4k"))
    assert r.model_if(16) == "model"
    assert r.model_if(17) == "model"  # 1-sized axis divides everything
    # on a 1×1 mesh everything divides; the guard logic itself:
    from repro.sharding.spec import ShardingRules

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    rr = ShardingRules(FakeMesh(), ("data",), "model", fsdp_axes=("data",))
    assert rr.model_if(51865) is None  # whisper vocab does not divide
    assert rr.model_if(49152) == "model"
    assert rr.fsdp_if(24) is None
    assert rr.fsdp_if(4096) == ("data",)


def test_param_shardings_cover_every_leaf():
    mesh = _mesh()
    for arch in ("gemma2-9b", "mixtral-8x7b", "mamba2-370m", "whisper-tiny",
                 "jamba-v0.1-52b", "llava-next-34b"):
        cfg = get_config(arch).reduced()
        from repro.launch.steps import abstract_params

        p = abstract_params(cfg)
        sh = param_shardings(make_rules(mesh, get_shape("train_4k")), p)
        n_p = len(jax.tree.leaves(p))
        n_s = len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
        assert n_p == n_s


def test_cache_shardings_structure():
    mesh = _mesh()
    cfg = get_config("gemma2-9b").reduced()
    from repro.models import init_caches

    caches = jax.eval_shape(lambda: init_caches(cfg, 4, 64))
    sh = cache_shardings(make_rules(mesh, get_shape("decode_32k")), caches)
    assert len(jax.tree.leaves(caches)) == len(
        jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))
    )


# ---------------------------------------------------------------------------
# HLO model parser
# ---------------------------------------------------------------------------

HLO_SAMPLE = """\
HloModule test

%body.1 (arg.1: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg.1 = (s32[], f32[8,16]{1,0}) parameter(0)
  %gte.0 = s32[] get-tuple-element(%arg.1), index=0
  %gte.1 = f32[8,16]{1,0} get-tuple-element(%arg.1), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%gte.1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[2,8]<=[16], use_global_device_ids=true, to_apply=%add.1
  ROOT %tuple.1 = (s32[], f32[8,16]{1,0}) tuple(%gte.0, %ar)
}

%cond.1 (arg.2: (s32[], f32[8,16])) -> pred[] {
  %arg.2 = (s32[], f32[8,16]{1,0}) parameter(0)
  %gte.2 = s32[] get-tuple-element(%arg.2), index=0
  %c10 = s32[] constant(10)
  ROOT %cmp = pred[] compare(%gte.2, %c10), direction=LT
}

%add.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add.2 = f32[] add(%a, %b)
}

ENTRY %main.1 (p0: f32[8,16]) -> (s32[], f32[8,16]) {
  %p0 = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t = (s32[], f32[8,16]{1,0}) tuple(%zero, %p0)
  ROOT %loop = (s32[], f32[8,16]{1,0}) while(%t), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
}
"""


def test_hlo_model_trip_counts():
    m = HloModel(HLO_SAMPLE)
    s = m.summary()
    # dot: 2·8·16·16 = 4096 flops × 10 trips
    assert s["dot_flops"] == 4096 * 10
    # all-reduce over groups of 8: 8·16·4 bytes × 2·(7/8) × 10
    expected_wire = 8 * 16 * 4 * 2 * (7 / 8) * 10
    assert abs(s["collective_wire_bytes"] - expected_wire) < 1e-6
    assert s["num_collectives"] == 10
    assert s["unknown_trip_whiles"] == 0


def test_hlo_model_without_trip_annotation():
    txt = HLO_SAMPLE.replace(
        ', backend_config={"known_trip_count":{"n":"10"}}', ""
    )
    m = HloModel(txt)
    s = m.summary()
    assert s["dot_flops"] == 4096  # counted once
    assert s["unknown_trip_whiles"] == 1  # and flagged


def test_parse_collectives_legacy():
    res = parse_collectives(HLO_SAMPLE)
    assert res["ops"]["all-reduce"]["count"] == 1


def test_parse_hlo_computations():
    comps = parse_hlo(HLO_SAMPLE)
    assert "body.1" in comps and "__entry__" in comps
    assert "dot.1" in comps["body.1"].ops
