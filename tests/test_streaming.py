"""Out-of-core streaming DFG (Claim C1) and the memmap log tier."""

import numpy as np
import pytest

from repro.core import (
    InMemoryDFGBaseline,
    StreamingDFGMiner,
    dfg_numpy,
    streaming_dfg,
)
from repro.core.baseline import LogTooLargeError
from repro.data import ProcessSpec, generate_memmap_log, generate_repository


def _rows_from_log(log):
    for a, c, t in log.iter_chunks():
        for i in range(a.shape[0]):
            yield int(c[i]), int(a[i]), float(t[i])


@pytest.fixture(scope="module")
def small_log(tmp_path_factory):
    path = tmp_path_factory.mktemp("log") / "mm"
    return generate_memmap_log(
        str(path), 20_000, ProcessSpec(num_activities=15, seed=21), seed=21,
        batch_traces=300,
    )


def test_memmap_log_is_time_ordered(small_log):
    prev = -np.inf
    for _, _, t in small_log.iter_chunks(chunk_rows=4096):
        assert t.min() >= prev
        assert (np.diff(t) >= 0).all()
        prev = t.max()


def test_streaming_matches_in_memory_baseline(small_log):
    psi_stream = streaming_dfg(small_log, chunk_rows=1024)
    base = InMemoryDFGBaseline()
    psi_mem = base.dfg(_rows_from_log(small_log), small_log.num_activities)
    np.testing.assert_array_equal(psi_stream, psi_mem)


def test_streaming_chunk_size_invariance(small_log):
    psis = [
        streaming_dfg(small_log, chunk_rows=cr) for cr in (128, 1024, 10**6)
    ]
    for p in psis[1:]:
        np.testing.assert_array_equal(p, psis[0])


def test_streaming_miner_interleaved_cases():
    # two cases interleaved in time order
    act = np.array([0, 1, 1, 2, 2, 0], dtype=np.int32)
    case = np.array([0, 1, 0, 1, 0, 1], dtype=np.int32)
    time = np.arange(6, dtype=np.float64)
    miner = StreamingDFGMiner(3)
    # feed one row at a time — worst case chunking
    for i in range(6):
        miner.update(act[i : i + 1], case[i : i + 1], time[i : i + 1])
    psi = miner.finalize()
    # case 0: 0 -> 1 -> 2 ; case 1: 1 -> 2 -> 0
    expected = np.zeros((3, 3), dtype=np.int64)
    expected[0, 1] += 1
    expected[1, 2] += 2
    expected[2, 0] += 1
    np.testing.assert_array_equal(psi, expected)


def test_time_window_uses_index(small_log):
    tmin = float(small_log.time[0])
    tmax = float(small_log.time[-1])
    mid0 = tmin + 0.25 * (tmax - tmin)
    mid1 = tmin + 0.5 * (tmax - tmin)
    lo, hi = small_log.rows_for_window(mid0, mid1)
    assert 0 < lo < hi < small_log.num_events
    psi = streaming_dfg(small_log, time_window=(mid0, mid1))
    # equivalent full-scan-with-filter result
    base = InMemoryDFGBaseline()
    psi_mem = base.dfg(
        _rows_from_log(small_log), small_log.num_activities,
        time_window=(mid0, mid1),
    )
    np.testing.assert_array_equal(psi, psi_mem)


def test_in_memory_baseline_respects_memory_budget(small_log):
    base = InMemoryDFGBaseline(memory_budget_bytes=1000)  # absurdly small
    with pytest.raises(LogTooLargeError):
        base.dfg(_rows_from_log(small_log), small_log.num_activities)


def test_streaming_total_mass(small_log):
    """Σψ = E - (#cases) for a fully-scanned log (each case contributes
    len-1 pairs)."""
    psi = streaming_dfg(small_log)
    ncases = np.unique(np.asarray(small_log.case)).shape[0]
    assert psi.sum() == small_log.num_events - ncases


def test_repository_and_streaming_agree():
    repo = generate_repository(500, ProcessSpec(num_activities=10, seed=33))
    from repro.core import dfg_from_repository

    psi_repo = dfg_from_repository(repo)
    miner = StreamingDFGMiner(10)
    # feed the repository's canonical stream re-sorted by time (interleaved)
    order = np.argsort(repo.event_time, kind="stable")
    miner.update(
        repo.event_activity[order], repo.event_trace[order], repo.event_time[order]
    )
    np.testing.assert_array_equal(miner.finalize(), psi_repo)


# ---------------------------------------------------------------------------
# append-mode writer + resumable miner state (delta-plan substrate)
# ---------------------------------------------------------------------------


def test_open_append_grows_log(tmp_path):
    from repro.core import MemmapLog, MemmapLogWriter

    w = MemmapLog.create(str(tmp_path / "log"), 4, 3, 2, chunk_rows=2)
    w.append(
        np.array([0, 1, 2, 1], np.int32),
        np.array([0, 0, 1, 1], np.int32),
        np.array([0.0, 1.0, 2.0, 3.0]),
    )
    log = w.close()

    aw = MemmapLogWriter.open_append(str(tmp_path / "log"))
    # new activity id 3 and case id 2 grow the vocabularies
    aw.append(
        np.array([3, 0], np.int32),
        np.array([2, 0], np.int32),
        np.array([3.5, 4.0]),
    )
    grown = aw.close()

    assert grown.num_events == 6
    assert grown.num_activities == 4
    assert grown.num_traces == 3
    np.testing.assert_array_equal(
        np.asarray(grown.activity), [0, 1, 2, 1, 3, 0]
    )
    np.testing.assert_array_equal(np.asarray(grown.time[:4]), np.asarray(log.time))
    # the old handle still views the old row count
    assert log.num_events == 4


def test_append_rejects_time_disorder(tmp_path):
    from repro.core import MemmapLog, MemmapLogWriter

    w = MemmapLog.create(str(tmp_path / "log"), 2, 2, 1, chunk_rows=2)
    w.append(
        np.array([0, 1], np.int32), np.array([0, 0], np.int32),
        np.array([0.0, 5.0]),
    )
    w.close()
    aw = MemmapLogWriter.open_append(str(tmp_path / "log"))
    with pytest.raises(ValueError, match="time-ordered"):
        aw.append(
            np.array([1], np.int32), np.array([0], np.int32),
            np.array([4.0]),  # before the stored last time
        )
    with pytest.raises(ValueError, match="time-ordered"):
        aw.append(
            np.array([1, 1], np.int32), np.array([0, 0], np.int32),
            np.array([7.0, 6.0]),  # internally unsorted
        )


def test_memmap_append_convenience(small_log, tmp_path):
    import shutil

    from repro.core import MemmapLog

    path = str(tmp_path / "copy")
    shutil.copytree(small_log.path, path)
    log = MemmapLog.open(path)
    t_last = float(log.time[-1])
    grown = log.append(
        np.array([0, 1], np.int32),
        np.array([0, 0], np.int32),
        np.array([t_last + 1.0, t_last + 2.0]),
    )
    assert grown.num_events == log.num_events + 2
    np.testing.assert_array_equal(
        np.asarray(grown.activity[: log.num_events]), np.asarray(log.activity)
    )


def test_miner_snapshot_restore_is_exact(small_log):
    """Splitting a scan at any point and resuming from a snapshot must be
    bit-identical to one continuous pass (Ψ, open-case tails, counters)."""
    full = streaming_dfg(small_log)
    for split in (0, 1, 7_919, small_log.num_events):
        miner = StreamingDFGMiner(small_log.num_activities)
        for a, c, t in small_log.iter_chunks(row_range=(0, split)):
            miner.update(a, c, t)
        resumed = StreamingDFGMiner.restore(miner.snapshot())
        # scribbling on the original after the snapshot must not leak
        miner.psi[:] = -1
        miner.last_by_case.clear()
        for a, c, t in small_log.iter_chunks(
            row_range=(split, small_log.num_events)
        ):
            resumed.update(a, c, t)
        np.testing.assert_array_equal(resumed.finalize(), full)
        assert resumed.events_seen == small_log.num_events


def test_miner_restore_pads_grown_vocabulary():
    from repro.core import StreamingDFGMiner

    miner = StreamingDFGMiner(2)
    miner.update(
        np.array([0, 1], np.int32), np.array([0, 0], np.int32),
        np.array([0.0, 1.0]),
    )
    big = StreamingDFGMiner.restore(miner.snapshot(), num_activities=4)
    big.update(
        np.array([3], np.int32), np.array([0], np.int32), np.array([2.0])
    )
    want = np.zeros((4, 4), np.int64)
    want[0, 1] = 1
    want[1, 3] = 1  # boundary pair via the carried per-case tail
    np.testing.assert_array_equal(big.finalize(), want)
    with pytest.raises(ValueError):
        StreamingDFGMiner.restore(miner.snapshot(), num_activities=1)


def test_aborted_append_leaves_no_orphans(tmp_path):
    """A writer discarded before close() commits nothing: the next
    open_append truncates its orphan bytes instead of misaligning."""
    import os

    from repro.core import MemmapLog, MemmapLogWriter

    w = MemmapLog.create(str(tmp_path / "log"), 2, 2, 1, chunk_rows=2)
    w.append(
        np.array([0, 1], np.int32), np.array([0, 0], np.int32),
        np.array([0.0, 5.0]),
    )
    log = w.close()

    aw = MemmapLogWriter.open_append(log.path)
    aw.append(  # written to disk, but never committed (no close)
        np.array([1], np.int32), np.array([0], np.int32), np.array([6.0])
    )
    with pytest.raises(ValueError):
        aw.append(  # aborts the writer mid-sequence
            np.array([0], np.int32), np.array([0], np.int32), np.array([1.0])
        )
    del aw
    assert os.path.getsize(os.path.join(log.path, "activity.i32")) > 2 * 4

    grown = log.append(
        np.array([0], np.int32), np.array([0], np.int32), np.array([9.0])
    )
    assert grown.num_events == 3  # the uncommitted row did not leak in
    np.testing.assert_array_equal(np.asarray(grown.activity), [0, 1, 0])
    np.testing.assert_array_equal(np.asarray(grown.time), [0.0, 5.0, 9.0])
