"""Out-of-core streaming DFG (Claim C1) and the memmap log tier."""

import numpy as np
import pytest

from repro.core import (
    InMemoryDFGBaseline,
    StreamingDFGMiner,
    dfg_numpy,
    streaming_dfg,
)
from repro.core.baseline import LogTooLargeError
from repro.data import ProcessSpec, generate_memmap_log, generate_repository


def _rows_from_log(log):
    for a, c, t in log.iter_chunks():
        for i in range(a.shape[0]):
            yield int(c[i]), int(a[i]), float(t[i])


@pytest.fixture(scope="module")
def small_log(tmp_path_factory):
    path = tmp_path_factory.mktemp("log") / "mm"
    return generate_memmap_log(
        str(path), 20_000, ProcessSpec(num_activities=15, seed=21), seed=21,
        batch_traces=300,
    )


def test_memmap_log_is_time_ordered(small_log):
    prev = -np.inf
    for _, _, t in small_log.iter_chunks(chunk_rows=4096):
        assert t.min() >= prev
        assert (np.diff(t) >= 0).all()
        prev = t.max()


def test_streaming_matches_in_memory_baseline(small_log):
    psi_stream = streaming_dfg(small_log, chunk_rows=1024)
    base = InMemoryDFGBaseline()
    psi_mem = base.dfg(_rows_from_log(small_log), small_log.num_activities)
    np.testing.assert_array_equal(psi_stream, psi_mem)


def test_streaming_chunk_size_invariance(small_log):
    psis = [
        streaming_dfg(small_log, chunk_rows=cr) for cr in (128, 1024, 10**6)
    ]
    for p in psis[1:]:
        np.testing.assert_array_equal(p, psis[0])


def test_streaming_miner_interleaved_cases():
    # two cases interleaved in time order
    act = np.array([0, 1, 1, 2, 2, 0], dtype=np.int32)
    case = np.array([0, 1, 0, 1, 0, 1], dtype=np.int32)
    time = np.arange(6, dtype=np.float64)
    miner = StreamingDFGMiner(3)
    # feed one row at a time — worst case chunking
    for i in range(6):
        miner.update(act[i : i + 1], case[i : i + 1], time[i : i + 1])
    psi = miner.finalize()
    # case 0: 0 -> 1 -> 2 ; case 1: 1 -> 2 -> 0
    expected = np.zeros((3, 3), dtype=np.int64)
    expected[0, 1] += 1
    expected[1, 2] += 2
    expected[2, 0] += 1
    np.testing.assert_array_equal(psi, expected)


def test_time_window_uses_index(small_log):
    tmin = float(small_log.time[0])
    tmax = float(small_log.time[-1])
    mid0 = tmin + 0.25 * (tmax - tmin)
    mid1 = tmin + 0.5 * (tmax - tmin)
    lo, hi = small_log.rows_for_window(mid0, mid1)
    assert 0 < lo < hi < small_log.num_events
    psi = streaming_dfg(small_log, time_window=(mid0, mid1))
    # equivalent full-scan-with-filter result
    base = InMemoryDFGBaseline()
    psi_mem = base.dfg(
        _rows_from_log(small_log), small_log.num_activities,
        time_window=(mid0, mid1),
    )
    np.testing.assert_array_equal(psi, psi_mem)


def test_in_memory_baseline_respects_memory_budget(small_log):
    base = InMemoryDFGBaseline(memory_budget_bytes=1000)  # absurdly small
    with pytest.raises(LogTooLargeError):
        base.dfg(_rows_from_log(small_log), small_log.num_activities)


def test_streaming_total_mass(small_log):
    """Σψ = E - (#cases) for a fully-scanned log (each case contributes
    len-1 pairs)."""
    psi = streaming_dfg(small_log)
    ncases = np.unique(np.asarray(small_log.case)).shape[0]
    assert psi.sum() == small_log.num_events - ncases


def test_repository_and_streaming_agree():
    repo = generate_repository(500, ProcessSpec(num_activities=10, seed=33))
    from repro.core import dfg_from_repository

    psi_repo = dfg_from_repository(repo)
    miner = StreamingDFGMiner(10)
    # feed the repository's canonical stream re-sorted by time (interleaved)
    order = np.argsort(repo.event_time, kind="stable")
    miner.update(
        repo.event_activity[order], repo.event_trace[order], repo.event_time[order]
    )
    np.testing.assert_array_equal(miner.finalize(), psi_repo)
