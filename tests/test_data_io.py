"""Synthetic log generator properties + CSV/XES IO + LM pipeline."""

import io

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import check_columnar, dfg_from_repository
from repro.data import ProcessSpec, generate_memmap_log, generate_repository
from repro.data.lm_data import TokenPipeline
from repro.data.xes import read_csv, read_xes, write_csv, write_xes


def test_generator_deterministic():
    r1 = generate_repository(100, ProcessSpec(num_activities=10, seed=5), seed=5)
    r2 = generate_repository(100, ProcessSpec(num_activities=10, seed=5), seed=5)
    np.testing.assert_array_equal(r1.event_activity, r2.event_activity)
    np.testing.assert_array_equal(r1.event_time, r2.event_time)


def test_generator_sound_and_plausible():
    repo = generate_repository(500, ProcessSpec(num_activities=20, seed=2))
    assert check_columnar(repo).ok
    assert repo.num_traces == 500
    lens = np.bincount(repo.event_trace)
    assert lens.min() >= 1
    assert 5 < lens.mean() < 30  # geometric around mean_trace_len=12


def test_memmap_log_target_size(tmp_path):
    log = generate_memmap_log(str(tmp_path / "l"), 30_000,
                              ProcessSpec(num_activities=8, seed=1),
                              batch_traces=200)
    assert abs(log.num_events - 30_000) < 300  # lands near the target
    t = np.asarray(log.time)
    assert (np.diff(t) >= 0).all()  # globally time-ordered


def test_csv_roundtrip():
    repo = generate_repository(50, ProcessSpec(num_activities=6, seed=3))
    buf = io.StringIO()
    write_csv(repo, buf)
    buf.seek(0)
    back = read_csv(buf)
    np.testing.assert_array_equal(
        dfg_from_repository(repo), dfg_from_repository(back)
    )


def test_xes_roundtrip():
    repo = generate_repository(30, ProcessSpec(num_activities=5, seed=9))
    buf = io.StringIO()
    write_xes(repo, buf)
    buf.seek(0)
    back = read_xes(buf)
    assert back.num_events == repo.num_events
    np.testing.assert_array_equal(
        dfg_from_repository(repo), dfg_from_repository(back)
    )


def _dfg_by_name(repo):
    psi = dfg_from_repository(repo)
    out = {}
    for i, a in enumerate(repo.activity_names):
        for j, b in enumerate(repo.activity_names):
            if psi[i, j]:
                out[(a, b)] = int(psi[i, j])
    return out


@settings(max_examples=20, deadline=None)
@given(n=st.integers(5, 80), seed=st.integers(0, 1000))
def test_csv_roundtrip_property(n, seed):
    """Name-keyed DFG equality: the roundtripped vocab only contains
    *observed* activities, so matrix indices may shift — counts must not."""
    repo = generate_repository(n, ProcessSpec(num_activities=7, seed=seed),
                               seed=seed)
    buf = io.StringIO()
    write_csv(repo, buf)
    buf.seek(0)
    back = read_csv(buf)
    assert _dfg_by_name(repo) == _dfg_by_name(back)


def test_token_pipeline_markov_learnable():
    p = TokenPipeline(vocab_size=32, batch=4, seq_len=64, seed=0, branching=4)
    ent = p.bigram_entropy()
    assert 0 < ent < np.log(32)  # strictly easier than uniform
    b = p(0)
    assert b["tokens"].shape == (4, 64)
    assert b["tokens"].max() < 32


def test_token_pipeline_uniform():
    p = TokenPipeline(vocab_size=16, batch=2, seq_len=8, mode="uniform")
    assert abs(p.bigram_entropy() - np.log(16)) < 1e-9
