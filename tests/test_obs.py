"""repro.obs — per-query execution traces, the lock-protected metrics
registry (counters / streaming histograms / exports), planner drift
detection, self-mining forensics, and the serving-layer introspection
sinks."""

import logging
import shutil
import threading

import numpy as np
import pytest

from repro.core import dfg_numpy
from repro.data import ProcessSpec, generate_memmap_log, generate_repository
from repro.obs import (
    MetricsRegistry,
    QueryTrace,
    kernel_registry,
    prometheus_text,
)
from repro.obs.metrics import BUCKET_BOUNDS
from repro.obs.trace import NullTrace
from repro.query import Q, QueryEngine
from repro.serve.query_service import QueryService


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------


@pytest.fixture()
def repo():
    return generate_repository(300, ProcessSpec(num_activities=7, seed=3),
                               seed=3)


@pytest.fixture()
def engine():
    return QueryEngine()


@pytest.fixture(scope="module")
def base_log(tmp_path_factory):
    path = tmp_path_factory.mktemp("obs") / "base"
    return generate_memmap_log(
        str(path), 20_000, ProcessSpec(num_activities=8, seed=11), seed=11,
        batch_traces=300,
    )


@pytest.fixture()
def log_copy(base_log, tmp_path):
    path = str(tmp_path / "log")
    shutil.copytree(base_log.path, path)
    from repro.core import MemmapLog

    return MemmapLog.open(path)


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------


def test_histogram_percentiles_log_uniform():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    rng = np.random.default_rng(0)
    xs = 10.0 ** rng.uniform(-4, 0, 5000)  # 100 µs … 1 s, log-uniform
    for x in xs:
        h.observe(float(x))
    for q in (50.0, 95.0, 99.0):
        est = h.percentile(q)
        true = float(np.percentile(xs, q))
        # log-scale buckets: the estimate lands within one decade/4 step
        assert true / 2.5 <= est <= true * 2.5
    snap = h.snapshot()
    assert snap["count"] == 5000
    assert snap["min"] == pytest.approx(xs.min())
    assert snap["max"] == pytest.approx(xs.max())
    assert snap["sum"] == pytest.approx(xs.sum())
    assert snap["p50"] <= snap["p95"] <= snap["p99"] <= snap["max"]


def test_histogram_percentile_clamps_to_envelope():
    reg = MetricsRegistry()
    h = reg.histogram("x")
    h.observe(0.013)
    h.observe(0.013)
    # everything in one bucket: interpolation must not escape [min, max]
    assert h.percentile(50.0) == pytest.approx(0.013)
    assert h.percentile(99.0) == pytest.approx(0.013)
    assert reg.histogram("empty").percentile(95.0) == 0.0


def test_counter_and_histogram_thread_safety():
    reg = MetricsRegistry()
    c = reg.counter("hits")
    h = reg.histogram("lat")
    N, M = 8, 2000

    def work():
        for _ in range(M):
            c.inc()
            h.observe(1e-3)

    threads = [threading.Thread(target=work) for _ in range(N)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == N * M
    assert h.count == N * M
    assert h.sum == pytest.approx(N * M * 1e-3)


def test_counter_inc_returns_sequence():
    reg = MetricsRegistry()
    c = reg.counter("seq")
    assert [c.inc(), c.inc(), c.inc(5)] == [1, 2, 7]


def test_registry_get_or_create_and_labels():
    reg = MetricsRegistry()
    a = reg.counter("n", sink="dfg")
    b = reg.counter("n", sink="dfg")
    other = reg.counter("n", sink="histogram")
    assert a is b and a is not other
    a.inc(3)
    d = reg.to_dict()
    assert d["n{sink=dfg}"] == 3
    assert d["n{sink=histogram}"] == 0


def test_to_dict_floor_zeroes_small_counts():
    reg = MetricsRegistry()
    reg.counter("small").inc(2)
    reg.counter("big").inc(100)
    h = reg.histogram("few")
    h.observe(0.5)
    d = reg.to_dict(floor=5)
    assert d["small"] == 0 and d["big"] == 100
    assert d["few"]["count"] == 0 and d["few"]["sum"] == 0.0


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("engine_queries_total").inc(4)
    h = reg.histogram("query_latency_seconds", sink="dfg")
    h.observe(0.002)
    h.observe(0.004)
    reg.gauge("cache_ratio", lambda: 0.5)
    text = reg.to_prometheus()
    assert "# TYPE engine_queries_total counter" in text
    assert "engine_queries_total 4" in text
    assert "# TYPE query_latency_seconds histogram" in text
    assert 'le="+Inf"} 2' in text
    assert 'query_latency_seconds_count{sink="dfg"} 2' in text
    assert 'query_latency_seconds_sum{sink="dfg"} 0.006' in text
    assert "# TYPE cache_ratio gauge" in text
    # cumulative bucket counts are monotone and end at the total
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if line.startswith("query_latency_seconds_bucket")]
    assert cums == sorted(cums) and cums[-1] == 2
    assert prometheus_text(reg, MetricsRegistry()).startswith("# TYPE")


def test_json_lines_parse():
    import json

    reg = MetricsRegistry()
    reg.counter("a", x="1").inc()
    reg.histogram("b").observe(0.1)
    recs = [json.loads(l) for l in reg.to_json_lines().splitlines()]
    by_name = {r["name"]: r for r in recs}
    assert by_name["a"]["type"] == "counter" and by_name["a"]["value"] == 1
    assert by_name["b"]["type"] == "histogram" and by_name["b"]["count"] == 1
    assert by_name["a"]["labels"] == {"x": "1"}


def test_bucket_bounds_cover_engine_range():
    assert BUCKET_BOUNDS[0] == pytest.approx(1e-6)
    assert BUCKET_BOUNDS[-1] == pytest.approx(100.0)
    assert all(b < c for b, c in zip(BUCKET_BOUNDS, BUCKET_BOUNDS[1:]))


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------


def test_trace_slab_growth_and_spans():
    tr = QueryTrace(1, "dfg", "repository")
    for i in range(40):  # forces several slab doublings
        s = tr.begin(f"s{i}")
        tr.end(s)
    tr.finish()
    assert [s.name for s in tr.spans] == [f"s{i}" for i in range(40)]
    assert all(s.duration_s >= 0.0 for s in tr.spans)
    assert 0.0 < tr.coverage() <= 1.0


def test_trace_finish_closes_orphaned_spans():
    tr = QueryTrace(1, "dfg", "repository")
    tr.begin("never_ended")
    tr.finish()
    assert tr.spans[0].duration_s >= 0.0
    assert tr.to_dict()["spans"][0]["name"] == "never_ended"


def test_null_trace_is_inert():
    tr = NullTrace(0, "dfg", "repository")
    assert tr.enabled is False
    assert tr.begin("x") == 0
    tr.end(0)
    tr.finish()
    assert tr.spans == []


def test_every_result_carries_a_trace(repo, engine):
    res = Q.log(repo).using(engine).dfg()
    tr = res.trace
    assert tr is not None and tr.enabled
    names = [s.name for s in tr.spans]
    assert names == ["parse", "cache_probe", "plan", "scan", "sink"]
    assert tr.executed_backend == tr.planned_backend
    assert tr.predicted_cost_s is not None and tr.actual_cost_s is not None
    assert tr.rows_scanned == repo.num_events
    assert tr.coverage() >= 0.90
    assert tr.total_s == pytest.approx(res.wall_s, abs=5e-3) or res.wall_s > 0


def test_cache_hit_gets_its_own_trace(repo, engine):
    first = Q.log(repo).using(engine).dfg()
    hit = Q.log(repo).using(engine).dfg()
    assert hit.from_cache
    assert hit.trace is not first.trace
    assert hit.trace.executed_backend == "cache"
    assert hit.trace.from_cache
    assert hit.trace.planned_backend == first.physical.backend
    # hit latency is the hit's own (probe) time, not the original scan
    assert hit.wall_s == pytest.approx(hit.trace.total_s)


def test_trace_disabled_engine(repo):
    engine = QueryEngine(trace=False)
    res = Q.log(repo).using(engine).dfg()
    assert res.trace is None
    assert len(engine.telemetry) == 0
    # counters still work without tracing
    assert engine.stats.queries == 1 and engine.stats.executions == 1


def test_delta_trace_and_metrics(log_copy):
    engine = QueryEngine(memory_budget_events=0)  # streaming-first
    Q.log(log_copy).using(engine).dfg()
    rng = np.random.default_rng(7)
    n = 200
    act = rng.integers(0, log_copy.num_activities, n).astype(np.int32)
    case = rng.integers(0, log_copy.num_traces, n).astype(np.int32)
    times = float(log_copy.time[-1]) + np.sort(rng.uniform(0.0, 50.0, n))
    grown = log_copy.append(act, case, times)
    res = Q.log(grown).using(engine).dfg()
    tr = res.trace
    assert tr.executed_backend == "delta"
    assert tr.planned_backend == "delta"
    assert tr.delta_rows is not None
    start, hi = tr.delta_rows
    assert hi - start == n
    assert tr.rows_scanned == n
    assert "delta" in [s.name for s in tr.spans]
    snap = engine.metrics_snapshot()
    assert snap["engine_delta_hits_total"] == 1
    frac = snap["delta_suffix_fraction"]
    assert frac["count"] == 1
    assert 0.0 < frac["max"] < 0.5


def test_union_trace_has_branches(repo, engine):
    other = generate_repository(200, ProcessSpec(num_activities=7, seed=4),
                                seed=4)
    res = Q.logs((repo, "a"), (other, "b")).using(engine).dfg()
    tr = res.trace
    assert tr is not None
    assert [n for n, _ in tr.branches] == ["a", "b"]
    for _, sub in tr.branches:
        assert sub.executed_backend is not None
    assert "merge" in [s.name for s in tr.spans]
    assert engine.stats.union_queries == 1


def test_engine_stats_is_a_consistent_snapshot(repo):
    engine = QueryEngine()
    N = 6

    def work():
        for _ in range(20):
            Q.log(repo).using(engine).dfg()

    threads = [threading.Thread(target=work) for _ in range(N)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    st = engine.stats
    assert st.queries == N * 20
    assert st.executions + st.cache_hits == st.queries
    assert st.executions >= 1


def test_explain_after_diffs_prediction(repo, engine):
    res = Q.log(repo).using(engine).dfg()
    txt = Q.log(repo).using(engine).explain(after=res)
    assert "-- after: recorded trace --" in txt
    assert "executed: " in txt and "matched prediction" in txt
    assert "coverage" in txt and "scanned" in txt
    off = QueryEngine(trace=False)
    res_off = Q.log(repo).using(off).dfg()
    no_trace = Q.log(repo).using(off).explain(after=res_off)
    assert "none recorded" in no_trace


def test_drift_detection_fires_counter_and_warning(repo, caplog):
    engine = QueryEngine()
    engine.drift_ratio = 1.0 + 1e-9   # any mismatch is drift
    engine.drift_min_s = 0.0
    with caplog.at_level(logging.WARNING, logger="repro.obs"):
        res = Q.log(repo).using(engine).dfg()
    assert res.trace.drift is not None
    snap = engine.metrics_snapshot()
    key = f"planner_drift_total{{backend={res.trace.executed_backend}}}"
    assert snap[key] == 1
    assert any("planner_cost_drift" in r.message for r in caplog.records)


def test_no_drift_at_default_tolerance(repo, engine):
    res = Q.log(repo).using(engine).dfg()
    # the 16x band with a 5ms floor must not flag a sub-ms toy query
    assert res.trace.drift is None


# ---------------------------------------------------------------------------
# self-mining forensics
# ---------------------------------------------------------------------------


def test_forensics_dfg_matches_algorithm1_oracle(repo, engine):
    Q.log(repo).using(engine).dfg()
    Q.log(repo).using(engine).dfg()          # cache hit: shorter chain
    Q.log(repo).using(engine).histogram()
    own = engine.own_telemetry()
    res = Q.log(own).using(engine).dfg()
    # oracle: numpy DFG over the same repository's consecutive pairs
    src, dst, valid = own.df_pairs()
    expect = dfg_numpy(src, dst, valid, own.num_activities)
    assert res.names == own.activity_names
    np.testing.assert_array_equal(np.asarray(res.value), expect)
    # the mined process contains the full-scan chain parse → cache_probe
    i = res.names.index("parse")
    j = res.names.index("cache_probe")
    assert np.asarray(res.value)[i, j] >= 1


def test_forensics_ring_buffer_bounds_memory(repo):
    engine = QueryEngine(telemetry_max_events=10)
    for _ in range(8):
        Q.log(repo).using(engine).dfg()
    assert len(engine.telemetry) == 10
    assert engine.telemetry.dropped > 0
    snap = engine.metrics_snapshot()
    assert snap["telemetry_events"] == 10
    assert snap["telemetry_dropped_events"] == engine.telemetry.dropped


# ---------------------------------------------------------------------------
# kernel timing hook
# ---------------------------------------------------------------------------


def test_kernel_timings_land_in_global_registry():
    from repro.kernels.dfg_count import dfg_count

    before = kernel_registry().histogram(
        "kernel_seconds", kernel="dfg_count"
    ).count
    out = dfg_count(
        np.array([0, 1, 2], np.int32), np.array([1, 2, 0], np.int32),
        np.array([True, True, True]), num_activities=3,
    )
    assert np.asarray(out).sum() == 3
    h = kernel_registry().histogram("kernel_seconds", kernel="dfg_count")
    assert h.count == before + 1
    assert "kernel_seconds{kernel=dfg_count}" in QueryEngine().metrics_snapshot()


# ---------------------------------------------------------------------------
# serving-layer introspection
# ---------------------------------------------------------------------------


def test_service_trace_option(repo):
    svc = QueryService()
    svc.register("main", repo)
    out = svc.query({"log": "main", "sink": "dfg", "trace": True})
    assert out["trace"]["executed_backend"] == out["backend"]
    assert [s["name"] for s in out["trace"]["spans"]][:2] == [
        "parse", "cache_probe",
    ]
    plain = svc.query({"log": "main", "sink": "histogram"})
    assert "trace" not in plain


def test_service_forensics_sink(repo):
    svc = QueryService()
    svc.register("main", repo)
    empty = QueryService().query({"sink": "forensics"})
    assert empty["events"] == 0 and empty["psi"] == []
    svc.query({"log": "main", "sink": "dfg"})
    out = svc.query({"sink": "forensics"})
    assert out["events"] >= 5
    assert "scan" in out["names"]
    psi = np.asarray(out["psi"])
    assert psi.sum() >= 1


def test_service_forensics_floor(repo):
    svc = QueryService(forensics_floor=1000)
    svc.register("main", repo)
    svc.query({"log": "main", "sink": "dfg"})
    out = svc.query({"sink": "forensics"})
    assert out["floor"] == 1000
    assert np.asarray(out["psi"]).sum() == 0  # toy volume is all sub-floor


def test_service_forensics_floor_joins_log_policy(repo):
    from repro.core.views import AccessPolicy

    svc = QueryService()
    svc.register("main", repo, policy=AccessPolicy(min_group_count=7))
    svc.query({"log": "main", "sink": "dfg"})
    out = svc.query({"log": "main", "sink": "forensics"})
    assert out["floor"] == 7


def test_service_metrics_sink(repo):
    svc = QueryService(forensics_floor=2)
    svc.register("main", repo)
    svc.query({"log": "main", "sink": "dfg"})
    out = svc.query({"sink": "metrics"})
    assert out["metrics"]["engine_queries_total"] == 0  # 1 query, floor 2
    prom = svc.query({"sink": "metrics", "format": "prometheus"})
    assert "engine_queries_total" in prom["prometheus"]
    assert "kernel_seconds" in prom["prometheus"]
