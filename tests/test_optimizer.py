"""AdamW, schedule, clipping — hand-rolled optimizer correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainHParams
from repro.train import (
    OptState,
    adamw_update,
    global_norm,
    init_opt_state,
    lr_schedule,
)


def test_lr_schedule_shape():
    hp = TrainHParams(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(hp, jnp.int32(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1e-3) < 1e-9  # warmup peak
    assert lrs[100] < 1e-5  # cosine floor
    assert all(a <= b + 1e-12 for a, b in zip(lrs[:10], lrs[1:11]))  # rising


def test_global_norm():
    tree = {"a": jnp.ones((3,)) * 2.0, "b": jnp.ones((4,)) * 1.0}
    # sqrt(3·4 + 4·1) = 4
    assert abs(float(global_norm(tree)) - 4.0) < 1e-6


def test_adamw_converges_quadratic():
    """AdamW minimizes a convex quadratic — sanity of moments/bias corr."""
    hp = TrainHParams(
        learning_rate=0.1, warmup_steps=0, total_steps=10_000,
        weight_decay=0.0, grad_clip=1e9,
    )
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)))
    params = {"w": jnp.zeros((8, 8))}
    state = init_opt_state(params)
    loss_fn = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(300):
        g = jax.grad(loss_fn)(params)
        params, state, _ = adamw_update(hp, params, g, state)
    assert float(loss_fn(params)) < 1e-2


def test_grad_clip_applied():
    hp = TrainHParams(learning_rate=1.0, warmup_steps=0, grad_clip=1.0,
                      weight_decay=0.0)
    params = {"w": jnp.zeros((2,))}
    state = init_opt_state(params)
    huge = {"w": jnp.asarray([3e4, 4e4])}
    _, state2, metrics = adamw_update(hp, params, huge, state)
    assert float(metrics["grad_norm"]) == pytest.approx(5e4, rel=1e-3)
    # after clipping the effective gradient is unit norm → moments bounded
    assert float(global_norm(state2.mu)) < 0.2


def test_weight_decay_only_matrices():
    hp = TrainHParams(learning_rate=0.01, warmup_steps=0, weight_decay=0.5)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    state = init_opt_state(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new_p, _, _ = adamw_update(hp, params, zero_g, state)
    assert float(new_p["w"][0, 0]) < 1.0  # decayed
    assert float(new_p["b"][0]) == 1.0  # not decayed


def test_opt_state_structure_matches_params():
    params = {"a": jnp.zeros((3, 3)), "nested": {"b": jnp.zeros((2,))}}
    st = init_opt_state(params)
    assert jax.tree_util.tree_structure(st.mu) == jax.tree_util.tree_structure(
        params
    )
    assert int(st.step) == 0
