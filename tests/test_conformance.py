"""Token-replay conformance on discovered models."""

import numpy as np

from repro.core import (
    EventRepository,
    dfg_from_repository,
    discover_dependency_graph,
)
from repro.core.conformance import replay_fitness
from repro.data import ProcessSpec, generate_repository


def _discover(repo, **kw):
    psi = dfg_from_repository(repo)
    starts, ends = repo.trace_boundaries()
    return discover_dependency_graph(
        psi, repo.activity_names, starts, ends,
        min_count=kw.get("min_count", 1),
        min_dependency=kw.get("min_dependency", -1.0),
    )


def test_self_replay_is_perfect():
    """A model discovered from the log with no filtering replays the log
    with fitness 1."""
    repo = EventRepository.from_traces(
        [["a", "b", "c"], ["a", "c"], ["a", "b", "b", "c"]]
    )
    model = _discover(repo)
    res = replay_fitness(repo, model)
    assert res.fitness == 1.0
    assert res.perfectly_fitting == repo.num_traces
    assert res.deviating_edges == {}


def test_unseen_behaviour_detected():
    repo_train = EventRepository.from_traces([["a", "b", "c"]] * 10)
    model = _discover(repo_train)
    repo_test = EventRepository.from_traces(
        [["a", "b", "c"], ["a", "c", "b"]],  # second trace deviates
        activity_vocab=repo_train.activity_names,
    )
    res = replay_fitness(repo_test, model)
    assert res.trace_fitness[0] == 1.0
    assert res.trace_fitness[1] < 1.0
    assert ("a", "c") in res.deviating_edges or ("c", "b") in res.deviating_edges


def test_filtered_model_partial_fitness():
    """Filtering rare edges out of the model lowers replay fitness by
    exactly the traces using them."""
    traces = [["a", "b", "d"]] * 90 + [["a", "c", "d"]] * 10
    repo = EventRepository.from_traces(traces)
    model = _discover(repo, min_count=50)  # drops the a→c→d path
    res = replay_fitness(repo, model)
    assert res.perfectly_fitting == 90
    assert 0.5 < res.fitness < 1.0
    assert res.deviating_edges.get(("a", "c")) == 10


def test_replay_scales_vectorized():
    repo = generate_repository(2000, ProcessSpec(num_activities=15, seed=8))
    model = _discover(repo)
    res = replay_fitness(repo, model)
    assert res.fitness == 1.0  # unfiltered self-replay
    s = res.summary()
    assert s["total_traces"] == 2000


def test_empty_repo_fitness():
    repo = EventRepository.from_traces([])
    model = _discover(generate_repository(5, ProcessSpec(num_activities=3, seed=1)))
    res = replay_fitness(repo, model)
    assert res.fitness == 1.0
