"""Sharded graph tier — case-partitioned CSR shards behind the
``sharded-graph`` backend.

Pins: the psum-merge equivalence (sharded-graph ≡ the single-host engine ≡
the Algorithm 1 streaming oracle) across window / activity-filter / view /
union combinations and K ∈ {1, 2, 8}; per-shard delta resume (an append
rescans only the owning shard's suffix, asserted through
``EngineStats.rows_scanned``); the composite fingerprint's per-slot
invalidation; the two-tier graph store's spill/page-in path; the graph
histogram backend; and the planner's sharded rejections.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.streaming import streaming_dfg
from repro.core.views import ActivityView
from repro.data import ProcessSpec, generate_memmap_log
from repro.graph import open_sharded_log, partition_memmap_log
from repro.query import Q, QueryEngine, QueryPlanError
from repro.query.cache import fingerprint, split_sharded_fingerprint

EVENTS = 12_000


def _span(log):
    times = np.concatenate([t for _, _, t in log.iter_chunks()])
    return float(times[0]), float(times[-1])


def _assert_same_value(a, b):
    if isinstance(a, np.ndarray):
        np.testing.assert_array_equal(a, b)
        return
    if dataclasses.is_dataclass(a):
        assert type(a) is type(b)
        for f in dataclasses.fields(a):
            _assert_same_value(getattr(a, f.name), getattr(b, f.name))
        return
    assert a == b


@pytest.fixture(scope="module")
def base_log(tmp_path_factory):
    p = tmp_path_factory.mktemp("shard_base")
    return generate_memmap_log(
        str(p / "log"), EVENTS,
        ProcessSpec(num_activities=12, seed=31, horizon_days=90), seed=31,
    )


@pytest.fixture(scope="module")
def sharded_by_k(base_log, tmp_path_factory):
    p = tmp_path_factory.mktemp("shard_parts")
    return {
        k: partition_memmap_log(base_log, k, str(p / f"k{k}"))
        for k in (1, 2, 8)
    }


def _ops_cases(names, t_lo, t_hi):
    span = t_hi - t_lo
    w = (t_lo + 0.2 * span, t_lo + 0.7 * span)
    keep = names[2:9]
    view = ActivityView({n: f"g{i % 3}" for i, n in enumerate(names[:8])})
    return [
        {},
        {"window": w},
        {"keep": keep},
        {"view": view},
        {"window": w, "keep": keep},
        {"window": w, "keep": keep, "view": view},
    ]


def _apply(q, ops):
    if "window" in ops:
        q = q.window(*ops["window"])
    if "keep" in ops:
        q = q.activities(ops["keep"])
    if "view" in ops:
        q = q.view(ops["view"])
    return q


# ---------------------------------------------------------------------------
# psum-merge equivalence sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 2, 8])
def test_sharded_dfg_equals_single_host_and_oracle(
    base_log, sharded_by_k, k
):
    sh = sharded_by_k[k]
    names = sh.activity_labels()
    t_lo, t_hi = _span(base_log)
    eng, ref = QueryEngine(), QueryEngine()
    for ops in _ops_cases(names, t_lo, t_hi):
        rs = _apply(Q.log(sh).using(eng), ops).dfg(backend="sharded-graph")
        rr = _apply(Q.log(base_log).using(ref), ops).dfg()
        assert rs.physical.backend == "sharded-graph"
        np.testing.assert_array_equal(rs.value, rr.value)
        assert rs.names == rr.names
        if not ops:
            np.testing.assert_array_equal(rs.value, streaming_dfg(base_log))
        elif set(ops) == {"window"}:
            np.testing.assert_array_equal(
                rs.value,
                streaming_dfg(base_log, time_window=ops["window"]),
            )


@pytest.mark.parametrize("k", [1, 8])
def test_sharded_histogram_and_topology_sinks(base_log, sharded_by_k, k):
    sh = sharded_by_k[k]
    t_lo, t_hi = _span(base_log)
    w = (t_lo + 0.25 * (t_hi - t_lo), t_lo + 0.8 * (t_hi - t_lo))
    eng, ref = QueryEngine(), QueryEngine()

    hs = Q.log(sh).using(eng).window(*w).histogram(backend="sharded-graph")
    hr = Q.log(base_log).using(ref).window(*w).histogram()
    np.testing.assert_array_equal(hs.value, hr.value)

    ps = Q.log(sh).using(eng).window(*w).process_map(
        backend="sharded-graph"
    )
    pr = Q.log(base_log).using(ref).window(*w).process_map()
    _assert_same_value(ps.value, pr.value)

    ns = Q.log(sh).using(eng).neighborhood(
        sh.activity_labels()[3], k=2, backend="sharded-graph"
    )
    nr = Q.log(base_log).using(ref).neighborhood(
        sh.activity_labels()[3], k=2
    )
    _assert_same_value(ns.value, nr.value)


def test_sharded_union_branch_equals_plain_union(
    base_log, sharded_by_k, tmp_path
):
    other = generate_memmap_log(
        str(tmp_path / "other"), 4_000,
        ProcessSpec(num_activities=12, seed=7, horizon_days=90), seed=7,
    )
    ru = Q.logs((sharded_by_k[2], "s"), (other, "m")).using(
        QueryEngine()
    ).dfg()
    rr = Q.logs((base_log, "s"), (other, "m")).using(QueryEngine()).dfg()
    assert ru.physical.backend == "union"
    np.testing.assert_array_equal(ru.value, rr.value)
    assert ru.names == rr.names


# ---------------------------------------------------------------------------
# per-shard delta resume
# ---------------------------------------------------------------------------


def _fresh_shards(tmp_path, k=4, events=6_000):
    log = generate_memmap_log(
        str(tmp_path / "log"), events,
        ProcessSpec(num_activities=10, seed=13, horizon_days=60), seed=13,
    )
    return log, partition_memmap_log(log, k, str(tmp_path / "shards"))


def test_append_rescans_only_owning_shard(tmp_path):
    log, sh = _fresh_shards(tmp_path)
    eng = QueryEngine()
    cold = Q.log(sh).using(eng).dfg(backend="sharded-graph")
    assert not cold.from_cache
    assert eng.stats.rows_scanned == sh.num_events

    _, t_max = _span(log)
    batch = 5
    grown = sh.append(
        np.arange(batch, dtype=np.int32) % sh.num_activities,
        np.full(batch, 6, dtype=np.int32),  # one owning shard: 6 % 4 == 2
        t_max + 1.0 + np.arange(batch, dtype=np.float64),
    )
    before = eng.stats.rows_scanned
    warm = Q.log(grown).using(eng).dfg(backend="sharded-graph")
    assert not warm.from_cache
    # only the owning shard's graph extends, and only over the suffix
    assert eng.stats.rows_scanned - before == batch

    oracle = Q.log(grown).using(QueryEngine()).dfg()  # independent cold path
    np.testing.assert_array_equal(warm.value, oracle.value)

    again = Q.log(grown).using(eng).dfg(backend="sharded-graph")
    assert again.from_cache
    assert eng.stats.rows_scanned - before == batch  # no further scans


def test_append_moves_only_owning_fingerprint_slot(tmp_path):
    _, sh = _fresh_shards(tmp_path)
    slots0 = split_sharded_fingerprint(fingerprint(sh))
    _, t_max = _span(sh.shards[2])
    grown = sh.append(
        np.zeros(3, dtype=np.int32),
        np.full(3, 6, dtype=np.int32),  # 6 % 4 == 2
        t_max + 1.0 + np.arange(3, dtype=np.float64),
    )
    slots1 = split_sharded_fingerprint(fingerprint(grown))
    assert len(slots0) == len(slots1) == 4
    assert slots0[2] != slots1[2]
    for k in (0, 1, 3):
        assert slots0[k] == slots1[k]


# ---------------------------------------------------------------------------
# two-tier graph store
# ---------------------------------------------------------------------------


def test_two_tier_store_spills_and_pages_in(tmp_path):
    log, sh = _fresh_shards(tmp_path)
    eng = QueryEngine(max_graphs=2, graph_spill_dir=str(tmp_path / "spill"))
    t_lo, t_hi = _span(log)
    w = (t_lo + 0.3 * (t_hi - t_lo), t_lo + 0.9 * (t_hi - t_lo))

    r1 = Q.log(sh).using(eng).dfg(backend="sharded-graph")
    assert eng.graphs.stats.spills > 0  # 4 shard graphs, room for 2
    r2 = Q.log(sh).using(eng).window(*w).dfg(backend="sharded-graph")
    assert eng.graphs.stats.pageins > 0  # evicted shards came off disk

    ref = QueryEngine()
    np.testing.assert_array_equal(
        r1.value, Q.log(log).using(ref).dfg().value
    )
    np.testing.assert_array_equal(
        r2.value, Q.log(log).using(ref).window(*w).dfg().value
    )


def test_reopened_sharded_log_hits_same_cache_keys(tmp_path):
    _, sh = _fresh_shards(tmp_path)
    eng = QueryEngine()
    r1 = Q.log(sh).using(eng).dfg(backend="sharded-graph")
    reopened = open_sharded_log(sh.path)
    r2 = Q.log(reopened).using(eng).dfg(backend="sharded-graph")
    assert not r1.from_cache and r2.from_cache
    np.testing.assert_array_equal(r1.value, r2.value)


# ---------------------------------------------------------------------------
# graph histograms (the sub-query backend the sharded merge pins)
# ---------------------------------------------------------------------------


def test_histogram_graph_backend_equals_streaming(tmp_path):
    log, _ = _fresh_shards(tmp_path)
    t_lo, t_hi = _span(log)
    w = (t_lo + 0.2 * (t_hi - t_lo), t_lo + 0.6 * (t_hi - t_lo))
    eng, ref = QueryEngine(), QueryEngine()
    for ops in ({}, {"window": w}):
        hg = _apply(Q.log(log).using(eng), ops).histogram(backend="graph")
        hs = _apply(Q.log(log).using(ref), ops).histogram()
        assert hg.physical.backend == "graph"
        np.testing.assert_array_equal(hg.value, hs.value)


def test_windowed_graph_histogram_needs_event_tables(tmp_path):
    log, _ = _fresh_shards(tmp_path)
    t_lo, t_hi = _span(log)
    ooc = QueryEngine(memory_budget_events=100)  # topology-only graphs
    with pytest.raises(QueryPlanError, match="graph histograms"):
        Q.log(log).using(ooc).window(t_lo, t_hi).histogram(backend="graph")


# ---------------------------------------------------------------------------
# planner rejections
# ---------------------------------------------------------------------------


def test_planner_rejections(tmp_path):
    log, sh = _fresh_shards(tmp_path)
    eng = QueryEngine()
    with pytest.raises(QueryPlanError, match="requires a ShardedLog"):
        Q.log(log).using(eng).dfg(backend="sharded-graph")
    with pytest.raises(QueryPlanError, match="not available on a sharded"):
        Q.log(sh).using(eng).dfg(backend="graph")
    with pytest.raises(QueryPlanError, match="conformance"):
        Q.log(sh).using(eng).fitness()
    with pytest.raises(QueryPlanError, match="variants"):
        Q.log(sh).using(eng).top_variants(3).dfg(backend="sharded-graph")
