"""Multi-source query algebra: ``Q.logs`` union + cross-log compare.

Acceptance criterion: union DFG/histogram/variants and CompareSink results
are bit-identical to the Algorithm 1 oracle on the concatenated (resp.
per-log) repositories, across physical backends, **including after per-log
appends** — the delta path scans only the appended branch's suffix
(asserted via ``EngineStats.rows_scanned``).

The oracle here is engine-independent: concatenation goes through the flat
string event table of ``EventRepository.from_event_table`` and counting
through ``df_pairs`` + ``dfg_numpy`` (plus ``dfg_algorithm1`` on the literal
graph for the small case).
"""

import shutil

import numpy as np
import pytest

from repro.core import (
    ActivityView,
    EventRepository,
    MemmapLog,
    concat_repositories,
    dfg_algorithm1,
    dfg_numpy,
    discover_dependency_graph,
    paper_example_repo,
    replay_fitness,
    streaming_dfg,
    trace_variants,
)
from repro.core.dicing import pair_mask_for_window
from repro.data import ProcessSpec, generate_memmap_log, generate_repository
from repro.query import (
    Q,
    FromLogs,
    LogRef,
    QueryEngine,
    QueryPlanError,
    UnionSource,
    canonicalize,
    fingerprint,
    load_calibration,
    split_union_fingerprint,
)
from repro.query.ast import DFGSink, CompareSink
from repro.query.execute import memmap_log_name, repository_from_memmap


# ---------------------------------------------------------------------------
# oracles
# ---------------------------------------------------------------------------


def _concat_oracle(named_repos):
    """Engine-independent concatenation: the flat string event table through
    from_event_table, with log provenance."""
    cases, acts, times, logs = [], [], [], []
    for name, r in named_repos:
        for i in range(r.num_events):
            cases.append(f"{name}/{r.trace_names[int(r.event_trace[i])]}")
            acts.append(r.activity_names[int(r.event_activity[i])])
            times.append(float(r.event_time[i]))
            logs.append(name)
    return EventRepository.from_event_table(cases, acts, times, log_ids=logs)


def _reference_dfg(repo, window=None, keep=None, view=None):
    src, dst, valid = repo.df_pairs()
    if window is not None:
        valid = valid & pair_mask_for_window(repo, window)
    if keep is not None:
        ids = np.asarray([repo.activity_names.index(a) for a in keep])
        m = np.isin(repo.event_activity, ids)
        valid = valid & m[:-1] & m[1:]
    psi = dfg_numpy(src, dst, valid, repo.num_activities)
    if view is not None:
        psi = view.apply_to_dfg(psi, repo.activity_names)
    return psi


def _embed(psi, names, union_names):
    """Embed a branch-vocabulary Ψ into the union vocabulary."""
    out = np.zeros((len(union_names),) * 2, dtype=np.int64)
    ids = np.asarray([union_names.index(n) for n in names])
    out[np.ix_(ids, ids)] = psi
    return out


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def repo_a():
    return generate_repository(120, ProcessSpec(num_activities=7, seed=101))


@pytest.fixture(scope="module")
def repo_b():
    # overlapping-but-different vocabulary (act_000..009 vs 000..006)
    return generate_repository(90, ProcessSpec(num_activities=10, seed=202))


@pytest.fixture(scope="module")
def multilog_repo():
    """One repository holding two deployments via the L×T relation."""
    rng = np.random.default_rng(7)
    cases, acts, times, logs = [], [], [], []
    for li, log in enumerate(["canary", "prod"]):
        for c in range(40):
            n = int(rng.integers(2, 7))
            for k in range(n):
                cases.append(f"{log}_c{c}")
                acts.append(f"act_{int(rng.integers(0, 6)):03d}")
                times.append(float(li * 1000 + c * 10 + k))
                logs.append(log)
    return EventRepository.from_event_table(cases, acts, times, log_ids=logs)


@pytest.fixture()
def two_mmlogs(tmp_path):
    logs = []
    for i in range(2):
        logs.append(generate_memmap_log(
            str(tmp_path / f"mm{i}"), 4_000,
            ProcessSpec(num_activities=8 + 3 * i, seed=60 + i), seed=60 + i,
            batch_traces=120,
        ))
    return logs


# ---------------------------------------------------------------------------
# union sinks vs the Algorithm 1 oracle on the concatenation
# ---------------------------------------------------------------------------


def test_union_dfg_matches_algorithm1_all_backends():
    a = paper_example_repo()
    b = EventRepository.from_traces(
        [["a2", "a5", "a3"], ["a1", "a5"]],
        activity_vocab=["a1", "a2", "a3", "a5"],
    )
    oracle = _concat_oracle([("prod", a), ("canary", b)])
    want, _ = dfg_algorithm1(oracle.to_graph())
    for backend in ("auto", "numpy", "scatter", "onehot", "pallas"):
        eng = QueryEngine()
        res = Q.logs((a, "prod"), (b, "canary")).using(eng).dfg(backend=backend)
        assert res.physical.backend == "union"
        assert res.names == oracle.activity_names  # sorted union vocabulary
        np.testing.assert_array_equal(res.value, want)


def test_union_window_filter_view_equals_oracle(repo_a, repo_b):
    oracle = _concat_oracle([("a", repo_a), ("b", repo_b)])
    ts = oracle.event_time
    t0, t1 = float(np.quantile(ts, 0.2)), float(np.quantile(ts, 0.85))
    keep = oracle.activity_names[2:9]  # includes names absent from repo_a
    view = ActivityView({n: f"g{i % 3}" for i, n in
                         enumerate(oracle.activity_names[:8])})
    eng = QueryEngine()
    q = Q.logs((repo_a, "a"), (repo_b, "b")).using(eng)
    np.testing.assert_array_equal(
        q.window(t0, t1).dfg().value, _reference_dfg(oracle, window=(t0, t1))
    )
    np.testing.assert_array_equal(
        q.activities(keep).dfg().value, _reference_dfg(oracle, keep=keep)
    )
    res = q.window(t0, t1).activities(keep).view(view).dfg()
    np.testing.assert_array_equal(
        res.value,
        _reference_dfg(oracle, window=(t0, t1), keep=keep, view=view),
    )
    assert res.names == view.visible_names(oracle.activity_names)


def test_union_histogram_equals_oracle(repo_a, repo_b):
    oracle = _concat_oracle([("a", repo_a), ("b", repo_b)])
    res = Q.logs((repo_a, "a"), (repo_b, "b")).using(QueryEngine()).histogram()
    want = np.bincount(
        oracle.event_activity, minlength=oracle.num_activities
    )
    np.testing.assert_array_equal(res.value, want)
    assert res.names == oracle.activity_names


def test_union_variants_and_concat_repositories(repo_a, repo_b):
    """concat_repositories must equal the flat-table oracle column for
    column; the union variants sink runs on exactly that concatenation."""
    oracle = _concat_oracle([("a", repo_a), ("b", repo_b)])
    cc = concat_repositories([("a", repo_a), ("b", repo_b)])
    np.testing.assert_array_equal(cc.event_activity, oracle.event_activity)
    np.testing.assert_array_equal(cc.event_trace, oracle.event_trace)
    np.testing.assert_array_equal(cc.event_time, oracle.event_time)
    np.testing.assert_array_equal(cc.trace_log, oracle.trace_log)
    assert cc.trace_names == oracle.trace_names
    assert cc.log_names == oracle.log_names
    assert cc.activity_names == oracle.activity_names

    res = Q.logs((repo_a, "a"), (repo_b, "b")).using(QueryEngine()).variants(5)
    assert res.physical.backend == "concat"
    tv = trace_variants(oracle)
    np.testing.assert_array_equal(res.value.counts, tv.counts[:5])
    assert res.value.sequences == tv.sequences[:5]


def test_union_top_variants_materializes_concat(repo_a, repo_b):
    from repro.core import variant_filtered_repository

    oracle = _concat_oracle([("a", repo_a), ("b", repo_b)])
    res = Q.logs((repo_a, "a"), (repo_b, "b")).using(
        QueryEngine()
    ).top_variants(3).dfg()
    assert res.physical.backend == "concat"
    want = _reference_dfg(variant_filtered_repository(oracle, 3))
    np.testing.assert_array_equal(res.value, want)


def test_union_duplicated_source_counts_twice(repo_a):
    """Q.logs(x, x): branch names are uniquified and the union counts every
    branch — the oracle is the doubled concatenation."""
    eng = QueryEngine()
    res = Q.logs(repo_a, repo_a).using(eng).dfg()
    assert len(set(res.logical.source.split(","))) >= 1  # plan key stable
    np.testing.assert_array_equal(res.value, 2 * _reference_dfg(repo_a))


def test_union_with_empty_branch(repo_a):
    empty = EventRepository(
        event_activity=np.zeros((0,), np.int32),
        event_trace=np.zeros((0,), np.int32),
        event_time=np.zeros((0,), np.float64),
        trace_log=np.zeros((0,), np.int32),
        activity_names=list(repo_a.activity_names),
        trace_names=[],
        log_names=["empty"],
    )
    res = Q.logs((repo_a, "a"), (empty, "e")).using(QueryEngine()).dfg()
    np.testing.assert_array_equal(res.value, _reference_dfg(repo_a))


# ---------------------------------------------------------------------------
# memmap branches: mixed physical backends, per-branch delta
# ---------------------------------------------------------------------------


def test_union_mixed_memmap_and_repo(repo_a, two_mmlogs):
    log = two_mmlogs[0]
    eng = QueryEngine(memory_budget_events=100)  # memmap branch streams
    res = Q.logs((log, "disk"), (repo_a, "mem")).using(eng).dfg()
    notes = " ".join(res.physical.notes)
    assert "branch[disk]=streaming" in notes
    oracle = _concat_oracle([
        ("disk", repository_from_memmap(log, "disk")), ("mem", repo_a),
    ])
    np.testing.assert_array_equal(res.value, _reference_dfg(oracle))


def test_union_delta_rescans_only_the_appended_branch(two_mmlogs, tmp_path):
    """The satellite acceptance: append to one branch ⇒ the other branch's
    cached state is untouched; only the appended suffix is scanned."""
    paths = []
    for i, src in enumerate(two_mmlogs):
        p = str(tmp_path / f"copy{i}")
        shutil.copytree(src.path, p)
        paths.append(p)
    log_a, log_b = MemmapLog.open(paths[0]), MemmapLog.open(paths[1])

    eng = QueryEngine(memory_budget_events=0)  # streaming-first: resumable
    q = lambda la, lb: Q.logs((la, "a"), (lb, "b")).using(eng).dfg()  # noqa: E731
    first = q(log_a, log_b)
    assert eng.stats.rows_scanned == log_a.num_events + log_b.num_events
    assert q(log_a, log_b).from_cache  # union-level entry

    # append to branch a only
    rng = np.random.default_rng(3)
    n_app = 150
    act = rng.integers(0, log_a.num_activities, n_app).astype(np.int32)
    case = rng.integers(0, log_a.num_traces, n_app).astype(np.int32)
    times = float(log_a.time[-1]) + np.sort(rng.uniform(0, 50, n_app))
    grown_a = log_a.append(act, case, times)

    base = eng.stats.rows_scanned
    res = q(grown_a, log_b)
    assert not res.from_cache
    assert eng.stats.delta_hits == 1  # branch a resumed over its suffix
    assert eng.stats.rows_scanned - base == n_app  # branch b: zero rows
    oracle = _concat_oracle([
        ("a", repository_from_memmap(grown_a, "a")),
        ("b", repository_from_memmap(log_b, "b")),
    ])
    np.testing.assert_array_equal(res.value, _reference_dfg(oracle))

    # and the same for the other branch
    grown_b = log_b.append(
        act % log_b.num_activities, case % log_b.num_traces,
        float(log_b.time[-1]) + np.sort(rng.uniform(0, 50, n_app)),
    )
    base = eng.stats.rows_scanned
    res2 = q(grown_a, grown_b)
    assert eng.stats.delta_hits == 2
    assert eng.stats.rows_scanned - base == n_app
    oracle2 = _concat_oracle([
        ("a", repository_from_memmap(grown_a, "a")),
        ("b", repository_from_memmap(grown_b, "b")),
    ])
    np.testing.assert_array_equal(res2.value, _reference_dfg(oracle2))


def test_union_empty_window_short_circuits(two_mmlogs):
    """EMPTY_WINDOW under a union: canonical shared plan, zeros, no scan."""
    log_a, log_b = two_mmlogs
    eng = QueryEngine(memory_budget_events=0)
    q1 = Q.logs((log_a, "a"), (log_b, "b")).using(eng).window(5.0, 3.0)
    q2 = Q.logs((log_a, "a"), (log_b, "b")).using(eng).window(99.0, 7.0)
    p1, _ = canonicalize(q1.logical_plan(DFGSink()))
    p2, _ = canonicalize(q2.logical_plan(DFGSink()))
    assert p1.key() == p2.key()

    r1 = q1.dfg()
    assert not r1.value.any()
    assert r1.value.shape[0] == len(r1.names)
    assert eng.stats.rows_scanned == 0  # neither branch touched
    assert q2.dfg().from_cache  # differently phrased, same entry
    r3 = q1.histogram()
    assert not r3.value.any() and eng.stats.rows_scanned == 0
    # compare also short-circuits the Ψ matrices on the canonical empty
    # window; its whole-log fitness signal pays its streaming replay scans
    # exactly once (model discovery + per-branch replay), then the memo
    # serves every later compare without touching the logs again
    rc = Q.logs((log_a, "a"), (log_b, "b")).using(eng).window(5.0, 3.0).compare()
    assert not any(p.any() for p in rc.value.psis)
    after_fitness = eng.stats.rows_scanned
    assert after_fitness > 0  # real fitness even with budget 0 (streaming)
    rc2 = Q.logs((log_a, "a"), (log_b, "b")).using(eng).window(9.0, 2.0).compare()
    assert rc2.value.fitness == rc.value.fitness
    assert eng.stats.rows_scanned == after_fitness  # memo: no rescan


def test_union_fingerprint_is_composite_and_prefix_preserving(two_mmlogs):
    union = Q.logs((two_mmlogs[0], "a"), (two_mmlogs[1], "b")).source
    fp = fingerprint(union)
    parts = split_union_fingerprint(fp)
    assert [n for n, _ in parts] == ["a", "b"]
    for (_, bfp), log in zip(parts, two_mmlogs):
        assert bfp == fingerprint(log)  # per-branch prefix-preserving form
        assert bfp.startswith("memmap:")


def test_union_fingerprint_escapes_separator_injection(repo_a, repo_b):
    """A branch name containing '='/'|' must not be able to forge another
    union's composite key."""
    two = Q.logs((repo_a, "a"), (repo_b, "b")).source
    fp_two = fingerprint(two)
    forged_name = f"a={split_union_fingerprint(fp_two)[0][1]}|b"
    one = Q.logs((repo_a, forged_name)).source
    assert fingerprint(one) != fp_two
    # and names round-trip through the escape
    assert split_union_fingerprint(fingerprint(one))[0][0] == forged_name


# ---------------------------------------------------------------------------
# FromLogs + compare
# ---------------------------------------------------------------------------


def test_select_logs_is_the_lxt_dice(multilog_repo):
    sub = multilog_repo.select_logs(["prod"])
    assert sub.log_names == ["prod"]
    assert sub.activity_names == multilog_repo.activity_names
    keep = multilog_repo.trace_log == multilog_repo.log_names.index("prod")
    assert sub.num_traces == int(keep.sum())
    assert sub.trace_names == [
        t for t, k in zip(multilog_repo.trace_names, keep) if k
    ]
    with pytest.raises(ValueError):
        multilog_repo.select_logs(["nope"])


def test_qlogs_expands_multilog_repository(multilog_repo):
    q = Q.logs(multilog_repo)
    assert isinstance(q.source, UnionSource)
    assert q.source.branch_names == tuple(multilog_repo.log_names)
    # union of all logs == the whole repository
    res = q.using(QueryEngine()).dfg()
    np.testing.assert_array_equal(res.value, _reference_dfg(multilog_repo))


def test_compare_per_log_oracle_and_drift(multilog_repo):
    eng = QueryEngine()
    res = Q.logs(multilog_repo).using(eng).compare()
    cr = res.value
    assert cr.log_names == ("canary", "prod")
    union_names = list(multilog_repo.activity_names)
    for name, psi in zip(cr.log_names, cr.psis):
        sub = multilog_repo.select_logs([name])
        want = _embed(_reference_dfg(sub), sub.activity_names, union_names)
        np.testing.assert_array_equal(psi, want)
    np.testing.assert_array_equal(cr.diff, cr.psis[1] - cr.psis[0])
    np.testing.assert_array_equal(cr.diffs[0], np.zeros_like(cr.psis[0]))

    # fitness: every branch replayed against the reference branch's model
    ref = multilog_repo.select_logs(["canary"])
    s, d, v = ref.df_pairs()
    model = discover_dependency_graph(
        dfg_numpy(s, d, v, ref.num_activities), ref.activity_names,
        *ref.trace_boundaries(),
    )
    assert cr.fitness[0] == pytest.approx(
        replay_fitness(ref, model).fitness
    )
    assert cr.fitness[1] == pytest.approx(
        replay_fitness(multilog_repo.select_logs(["prod"]), model).fitness
    )


def test_compare_windowed_matches_per_log_reference(multilog_repo):
    ts = multilog_repo.event_time
    t0, t1 = float(np.quantile(ts, 0.1)), float(np.quantile(ts, 0.9))
    cr = Q.logs(multilog_repo).using(QueryEngine()).window(t0, t1).compare().value
    union_names = list(multilog_repo.activity_names)
    for name, psi in zip(cr.log_names, cr.psis):
        sub = multilog_repo.select_logs([name])
        want = _embed(
            _reference_dfg(sub, window=(t0, t1)), sub.activity_names,
            union_names,
        )
        np.testing.assert_array_equal(psi, want)


def test_compare_fitness_is_whole_log_and_memoized(multilog_repo):
    """fitness is documented as window-independent: an empty or sliding
    window reports the same tuple, served from the per-fingerprint memo."""
    eng = QueryEngine()
    base = Q.logs(multilog_repo).using(eng).compare().value
    empty = Q.logs(multilog_repo).using(eng).window(5.0, 5.0).compare().value
    assert empty.fitness == base.fitness
    assert not any(p.any() for p in empty.psis)

    calls = []
    real = eng._compute_compare_fitness

    def counting(union):
        calls.append(1)
        return real(union)

    eng._compute_compare_fitness = counting
    ts = multilog_repo.event_time
    for q in (0.3, 0.6, 0.9):  # a dashboard sliding its window
        t1 = float(np.quantile(ts, q))
        res = Q.logs(multilog_repo).using(eng).window(0.0, t1).compare()
        assert res.value.fitness == base.fitness
    assert calls == []  # memo hit for every window over unchanged data


def test_concat_rejects_colliding_trace_namespaces():
    r1 = EventRepository.from_traces([["p", "q"]], activity_vocab=["p", "q"])
    r2 = EventRepository.from_traces([["p"]], activity_vocab=["p", "q"])
    # branch "a" trace "x/t1" and branch "a/x" trace "t1" both namespace to
    # "a/x/t1" — must be an error, not silently merged traces
    r1 = type(r1)(
        event_activity=r1.event_activity, event_trace=r1.event_trace,
        event_time=r1.event_time, trace_log=r1.trace_log,
        activity_names=r1.activity_names, trace_names=["x/t1"],
        log_names=r1.log_names,
    )
    with pytest.raises(ValueError):
        concat_repositories([("a", r1), ("a/x", r2)])


def test_compare_fitness_streams_beyond_budget(two_mmlogs):
    """Out-of-budget branches no longer report None: model discovery and
    replay both run as one-pass streaming scans (repro.conformance)."""
    from repro.conformance import (
        StreamingModelDiscoverer,
        replay_fitness_streaming,
    )

    eng = QueryEngine(memory_budget_events=0)  # nothing materializes
    cr = Q.logs((two_mmlogs[0], "a"), (two_mmlogs[1], "b")).using(
        eng
    ).compare().value
    ref = two_mmlogs[0]
    disc = StreamingModelDiscoverer(ref.num_activities)
    for a, c, t in ref.iter_chunks():
        disc.update(a, c, t)
    model = disc.finalize(ref.activity_labels())
    want = tuple(
        replay_fitness_streaming(log, model).fitness for log in two_mmlogs
    )
    assert cr.fitness == pytest.approx(want)
    # the Ψ matrices still compare exactly (streamed per branch)
    np.testing.assert_array_equal(
        cr.psis[0],
        _embed(
            streaming_dfg(two_mmlogs[0]),
            two_mmlogs[0].activity_labels(),
            cr.names,
        ),
    )


# ---------------------------------------------------------------------------
# errors + builder edges
# ---------------------------------------------------------------------------


def test_union_and_compare_errors(repo_a, repo_b, two_mmlogs):
    with pytest.raises(QueryPlanError):
        Q.logs()
    with pytest.raises(QueryPlanError):
        Q.log(repo_a).using(QueryEngine()).compare()  # single source
    with pytest.raises(QueryPlanError):
        # compare needs >= 2 branches
        Q.logs((repo_a, "only")).using(QueryEngine()).compare()
    with pytest.raises(QueryPlanError):
        # barriers do not distribute under compare
        Q.logs((repo_a, "a"), (repo_b, "b")).using(
            QueryEngine()
        ).top_variants(2).compare()
    with pytest.raises(QueryPlanError):
        # pinned streaming cannot run a repository branch
        Q.logs((repo_a, "a"), (repo_b, "b")).using(
            QueryEngine()
        ).dfg(backend="streaming")
    with pytest.raises(QueryPlanError):
        # unknown activities validate against the union vocabulary
        Q.logs((repo_a, "a"), (repo_b, "b")).using(
            QueryEngine()
        ).activities(["nope"]).dfg()
    with pytest.raises(QueryPlanError):
        UnionSource([])
    with pytest.raises(QueryPlanError):
        FromLogs(repo_a, ["not-a-log"])
    with pytest.raises(QueryPlanError):
        # explicit duplicate names would silently double-count
        Q.logs((repo_a, "same"), (repo_b, "same"))
    with pytest.raises(QueryPlanError):
        # out-of-core union cannot materialize for variants
        Q.logs((two_mmlogs[0], "a"), (two_mmlogs[1], "b")).using(
            QueryEngine(memory_budget_events=100)
        ).variants()


def test_qlogs_flattens_and_uniquifies(repo_a, two_mmlogs):
    inner = Q.logs((repo_a, "x"), (two_mmlogs[0], "y")).source
    outer = Q.logs(inner, LogRef(repo_a, "z")).source
    assert outer.branch_names == ("x", "y", "z")
    dup = Q.logs(repo_a, repo_a).source
    assert len(set(dup.branch_names)) == 2
    # auto-uniquified names must themselves stay unique even when the
    # suffixed form collides with another auto-derived basename
    import dataclasses as dc

    named = lambda n: dc.replace(repo_a, log_names=[n])  # noqa: E731
    tricky = Q.logs(named("x#1"), named("x"), named("x")).source
    assert len(set(tricky.branch_names)) == 3


def test_single_logref_and_fromlogs_resolve_in_q_log(repo_a, multilog_repo):
    """LogRef/FromLogs are grammar sources: Q.log must accept them too."""
    res = Q.log(LogRef(repo_a, "a")).using(QueryEngine()).dfg()
    np.testing.assert_array_equal(res.value, _reference_dfg(repo_a))
    res2 = Q.log(FromLogs(multilog_repo, ("prod",))).using(QueryEngine()).dfg()
    np.testing.assert_array_equal(
        res2.value, _reference_dfg(multilog_repo.select_logs(["prod"]))
    )


def test_split_logs_equals_select_logs(multilog_repo):
    split = multilog_repo.split_logs(multilog_repo.log_names)
    for name, sub in split.items():
        want = multilog_repo.select_logs([name])
        np.testing.assert_array_equal(sub.event_activity, want.event_activity)
        np.testing.assert_array_equal(sub.event_trace, want.event_trace)
        np.testing.assert_array_equal(sub.event_time, want.event_time)
        assert sub.trace_names == want.trace_names
        assert sub.log_names == want.log_names

    # Q.logs expansion shares one split pass across sibling branches
    calls = []
    real = EventRepository.select_logs

    def counting(self, names):
        calls.append(tuple(names))
        return real(self, names)

    EventRepository.select_logs = counting
    try:
        res = Q.logs(multilog_repo).using(QueryEngine()).dfg()
    finally:
        EventRepository.select_logs = real
    assert calls == []  # resolved through split_logs, not per-branch dices
    np.testing.assert_array_equal(res.value, _reference_dfg(multilog_repo))


def test_union_cache_content_addressed_per_branch(repo_a, repo_b):
    eng = QueryEngine()
    import dataclasses as dc

    Q.logs((repo_a, "a"), (repo_b, "b")).using(eng).dfg()
    clone = dc.replace(repo_a, event_activity=repo_a.event_activity.copy())
    # equal bytes, same branch names → union-level cache hit
    assert Q.logs((clone, "a"), (repo_b, "b")).using(eng).dfg().from_cache
    # same bytes under a *different* branch name → different provenance
    assert not Q.logs((clone, "a2"), (repo_b, "b")).using(eng).dfg().from_cache


# ---------------------------------------------------------------------------
# satellite: repository_from_memmap provenance
# ---------------------------------------------------------------------------


def test_repository_from_memmap_derives_log_name(two_mmlogs):
    log = two_mmlogs[0]
    repo = repository_from_memmap(log)
    assert repo.log_names == [memmap_log_name(log)]
    assert repo.log_names != ["l1"]  # the old hardcoding
    assert repository_from_memmap(log, "prod").log_names == ["prod"]


# ---------------------------------------------------------------------------
# satellite: measured cost-model calibration
# ---------------------------------------------------------------------------


def test_calibration_fallback_and_load(tmp_path, monkeypatch):
    from repro.query.planner import (
        GRAPH_REPEAT_CROSSOVER,
        MEMORY_BUDGET_EVENTS,
        TINY_PAIRS,
    )

    from repro.query.planner import REPLAY_STREAMING_CROSSOVER

    from repro.query.planner import SHARDED_SINGLE_CROSSOVER

    from repro.query.planner import SLO_HOT_CUTOFF_S

    monkeypatch.delenv("GRAPHPM_BENCH_QUERY", raising=False)
    monkeypatch.delenv("GRAPHPM_BENCH_GRAPH", raising=False)
    monkeypatch.delenv("GRAPHPM_BENCH_CONFORMANCE", raising=False)
    monkeypatch.delenv("GRAPHPM_BENCH_SHARD", raising=False)
    monkeypatch.delenv("GRAPHPM_BENCH_SERVE", raising=False)
    missing = str(tmp_path / "nope.json")
    cal = load_calibration(
        missing, graph_path=missing, conformance_path=missing,
        shard_path=missing, serve_path=missing,
    )
    assert cal == {
        "tiny_pairs": TINY_PAIRS,
        "memory_budget_events": MEMORY_BUDGET_EVENTS,
        "graph_repeat_crossover": GRAPH_REPEAT_CROSSOVER,
        "replay_streaming_crossover": REPLAY_STREAMING_CROSSOVER,
        "sharded_single_crossover": SHARDED_SINGLE_CROSSOVER,
        "slo_hot_cutoff_s": SLO_HOT_CUTOFF_S,
        "curves": {},
    }

    bench = tmp_path / "BENCH_query.json"
    bench.write_text(
        '{"calibration": {"tiny_pairs": 512, '
        '"memory_budget_events": 2097152}}'
    )
    cal = load_calibration(str(bench))
    assert cal["tiny_pairs"] == 512
    assert cal["memory_budget_events"] == 2097152

    # clamped to sanity rails
    bench.write_text(
        '{"calibration": {"tiny_pairs": 1000000000, '
        '"memory_budget_events": 1}}'
    )
    cal = load_calibration(str(bench))
    assert cal["tiny_pairs"] == 4096
    assert cal["memory_budget_events"] == 1 << 20

    # corrupt file → static fallback
    bench.write_text("{not json")
    assert load_calibration(str(bench))["tiny_pairs"] == TINY_PAIRS


def test_engine_picks_up_calibration(tmp_path, monkeypatch):
    bench = tmp_path / "BENCH_query.json"
    bench.write_text('{"calibration": {"tiny_pairs": 777}}')
    monkeypatch.setenv("GRAPHPM_BENCH_QUERY", str(bench))
    assert QueryEngine().tiny_pairs == 777
    # explicit arguments always win over the calibration record
    assert QueryEngine(tiny_pairs=9).tiny_pairs == 9
    assert QueryEngine(calibration_path=str(bench)).tiny_pairs == 777


# ---------------------------------------------------------------------------
# serving: multi-log requests + cross-union policy guards
# ---------------------------------------------------------------------------


def test_service_union_and_compare(repo_a, repo_b):
    from repro.serve import QueryService

    svc = QueryService()
    svc.register("prod", repo_a)
    svc.register("canary", repo_b)
    oracle = _concat_oracle([("canary", repo_b), ("prod", repo_a)])

    out = svc.query({"logs": ["canary", "prod"], "sink": "dfg"})
    np.testing.assert_array_equal(
        np.asarray(out["psi"]), _reference_dfg(oracle)
    )
    assert out["logs"] == ["canary", "prod"] and out["backend"] == "union"
    assert svc.query({"logs": ["canary", "prod"], "sink": "dfg"})["from_cache"]

    cmp_out = svc.query({"logs": ["prod", "canary"], "sink": "compare"})
    assert set(cmp_out["psi"]) == {"prod", "canary"}
    np.testing.assert_array_equal(
        np.asarray(cmp_out["diff"]["canary"]),
        np.asarray(cmp_out["psi"]["canary"])
        - np.asarray(cmp_out["psi"]["prod"]),
    )
    assert set(cmp_out["fitness"]) == {"prod", "canary"}

    with pytest.raises(KeyError):
        svc.query({"logs": ["prod", "ghost"], "sink": "dfg"})
    with pytest.raises(QueryPlanError):
        # naming the same log twice would double-count its events
        svc.query({"logs": ["prod", "prod"], "sink": "dfg"})


def test_service_union_policy_guards(repo_a, repo_b):
    from repro.core.views import AccessDenied, AccessPolicy
    from repro.serve import QueryService

    view = ActivityView({n: "g" for n in repo_a.activity_names[:4]})
    svc = QueryService()
    svc.register("open", repo_a)
    svc.register("veiled", repo_b, policy=AccessPolicy(view=view))
    svc.register("veiled2", repo_a, policy=AccessPolicy(view=view))
    svc.register(
        "other_view", repo_a,
        policy=AccessPolicy(view=ActivityView({"act_000": "x"})),
    )
    svc.register("nodice", repo_a,
                 policy=AccessPolicy(time_windows_allowed=False))
    svc.register("floored", repo_a,
                 policy=AccessPolicy(min_group_count=10**9))

    # a view-protected log cannot be unioned with an unprotected one ...
    with pytest.raises(AccessDenied):
        svc.query({"logs": ["open", "veiled"], "sink": "compare"})
    # ... nor with a log under a different view
    with pytest.raises(AccessDenied):
        svc.query({"logs": ["veiled", "other_view"], "sink": "compare"})
    # identical views combine, and the result lives in group space
    out = svc.query({"logs": ["veiled", "veiled2"], "sink": "compare"})
    assert out["names"] == ["g"]

    # time dicing must be allowed by every member
    with pytest.raises(AccessDenied):
        svc.query({"logs": ["open", "nodice"], "sink": "dfg",
                   "window": [0.0, 1.0]})
    # the k-anonymity floor is the max across the union
    out = svc.query({"logs": ["open", "floored"], "sink": "dfg"})
    assert not np.asarray(out["psi"]).any()
    out = svc.query({"logs": ["open", "floored"], "sink": "compare"})
    assert not any(np.asarray(p).any() for p in out["psi"].values())
    # raw-activity filters stay denied under a view, union or not
    with pytest.raises(AccessDenied):
        svc.query({"logs": ["veiled", "veiled2"], "sink": "dfg",
                   "activities": [repo_b.activity_names[0]]})
