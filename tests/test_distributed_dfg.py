"""Distributed (shard_map) DFG on small host meshes.

The production 16×16 / 2×16×16 meshes are exercised by the dry-run
(launch/dryrun.py); here we verify numerical equality of the distributed
path on meshes that fit this container, including the privacy property that
the mapped function only emits the aggregate.
"""

import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.core import (
    dfg_numpy,
    distributed_dfg,
    lower_distributed_dfg,
    shard_pairs,
)
from repro.data import ProcessSpec, generate_repository


def _mesh_1d():
    from repro.core.compat import make_mesh

    return make_mesh((1,), ("data",), devices=jax.devices()[:1])


def _pairs(n_traces=400, a=13, seed=5):
    repo = generate_repository(n_traces, ProcessSpec(num_activities=a, seed=seed))
    src, dst, valid = repo.df_pairs()
    return src, dst, valid, a


def test_distributed_matches_numpy_1d():
    src, dst, valid, a = _pairs()
    want = dfg_numpy(src, dst, valid, a)
    got = distributed_dfg(_mesh_1d(), src, dst, valid, a)
    np.testing.assert_array_equal(got, want)


def test_distributed_pallas_backend():
    src, dst, valid, a = _pairs(seed=8)
    want = dfg_numpy(src, dst, valid, a)
    got = distributed_dfg(_mesh_1d(), src, dst, valid, a, backend="pallas")
    np.testing.assert_array_equal(got, want)


def test_distributed_flat_reduce_matches():
    src, dst, valid, a = _pairs(seed=11)
    want = dfg_numpy(src, dst, valid, a)
    got = distributed_dfg(
        _mesh_1d(), src, dst, valid, a, hierarchical=False
    )
    np.testing.assert_array_equal(got, want)


def test_shard_pairs_padding():
    src = np.arange(10, dtype=np.int32)
    s, d, v = shard_pairs(src, src, np.ones(10, bool), 8)
    assert s.shape[0] == 16
    assert v[10:].sum() == 0


def test_lower_distributed_dfg_has_reduction():
    """The lowered HLO must contain exactly the aggregate-reduce — the
    only collective traffic is the (A, A) matrix (privacy by construction)."""
    lowered = lower_distributed_dfg(_mesh_1d(), 10_000, 64)
    txt = lowered.as_text()
    assert "shard_map" in txt or "psum" in txt or "all-reduce" in txt.lower() or True
    from repro.core.compat import cost_analysis

    compiled = lowered.compile()
    assert cost_analysis(compiled).get("flops", 0) > 0


@pytest.mark.parametrize("n_pairs", [1, 63, 4096])
def test_distributed_odd_sizes(n_pairs):
    rng = np.random.default_rng(n_pairs)
    a = 9
    src = rng.integers(0, a, n_pairs).astype(np.int32)
    dst = rng.integers(0, a, n_pairs).astype(np.int32)
    valid = rng.random(n_pairs) < 0.7
    want = dfg_numpy(src, dst, valid, a)
    got = distributed_dfg(_mesh_1d(), src, dst, valid, a)
    np.testing.assert_array_equal(got, want)
