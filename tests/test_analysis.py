"""repro.analysis — lint rules on fixture trees and the real tree, baseline
round-trip, the lockdep runtime sanitizer, the Pallas resource checker, and
regression tests for the violations the lint surfaced."""

import json
import shutil
import threading
from pathlib import Path

import pytest

from repro.analysis import lockdep
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.framework import (
    Finding,
    Project,
    load_baseline,
    run_rules,
    save_baseline,
    split_findings,
)
from repro.analysis.kernels_check import (
    KernelResourceError,
    build_report,
    validate_blocks,
)

REPO_ROOT = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "analysis_fixtures"


def fixture_findings(tree, rules=None):
    return run_rules(Project(FIXTURES / tree), rules)


# ---------------------------------------------------------------------------
# Rule flag / pass cases on fixture trees
# ---------------------------------------------------------------------------


def test_unhandled_sink_is_flagged():
    found = fixture_findings("unhandled_sink", ["backend-coverage"])
    assert len(found) == 1
    f = found[0]
    assert "OrphanSink" in f.message
    assert f.path.endswith("query/planner.py")


def test_covered_sinks_pass_via_alias():
    # execute.py covers both sinks through the SINKS tuple alias
    found = fixture_findings("unhandled_sink", ["backend-coverage"])
    assert not any(f.path.endswith("execute.py") for f in found)


def test_unkeyed_plan_field_is_flagged():
    msgs = [f.message for f in fixture_findings(
        "unkeyed_field", ["cache-key-completeness"]
    )]
    assert any("unkeyed plan field: WindowSink.span" in m for m in msgs)
    assert any("MutableSink is not frozen=True" in m for m in msgs)
    assert any(
        "unkeyed plan field: ShardedDFGSink.num_shards" in m for m in msgs
    )
    assert any(
        "LogicalPlan.sink does not flow into the canonical payload" in m
        for m in msgs
    )


def test_unlocked_stats_mutation_is_flagged():
    msgs = [f.message for f in fixture_findings(
        "unlocked_stats", ["lock-discipline"]
    )]
    assert any(
        "StatsRegistry.reset: mutation of lock-protected attribute "
        "'counts'" in m
        for m in msgs
    )
    # annotated-only protection (no locked mutation site to infer from)
    assert any("AnnotatedRegistry.observe" in m and "'hists'" in m
               for m in msgs)
    # _locked-suffix helpers are exempt
    assert not any("_wipe_locked" in m for m in msgs)
    assert any("blocking call open()" in m for m in msgs)
    assert any("inconsistent lock order" in m for m in msgs)


def test_kernel_hygiene_is_flagged():
    msgs = [f.message for f in fixture_findings(
        "hygiene_bad", ["rng-time-hygiene"]
    )]
    assert any("time.time()" in m for m in msgs)
    assert any("np.random.uniform()" in m for m in msgs)
    assert any("time.perf_counter_ns()" in m for m in msgs)


def test_clean_tree_passes_every_rule():
    assert fixture_findings("clean_tree") == []


# ---------------------------------------------------------------------------
# Deliberate regressions against copies of the *real* engine files
# ---------------------------------------------------------------------------


def _copy_query_tree(tmp_path):
    qdir = tmp_path / "query"
    qdir.mkdir()
    for name in ("ast.py", "planner.py", "execute.py"):
        shutil.copy(REPO_ROOT / "src" / "repro" / "query" / name, qdir / name)
    return tmp_path


def test_new_sink_in_real_tree_is_caught(tmp_path):
    root = _copy_query_tree(tmp_path)
    with open(root / "query" / "ast.py", "a") as fh:
        fh.write(
            "\n\n@dataclasses.dataclass(frozen=True)\n"
            "class ShinyNewSink:\n    backend: str = 'auto'\n"
        )
    found = run_rules(Project(root), ["backend-coverage"])
    assert {f.path for f in found} == {"query/planner.py", "query/execute.py"}
    assert all("ShinyNewSink" in f.message for f in found)


def test_unkeyed_field_in_real_tree_is_caught(tmp_path):
    root = _copy_query_tree(tmp_path)
    with open(root / "query" / "ast.py", "a") as fh:
        fh.write(
            "\n\n@dataclasses.dataclass(frozen=True)\n"
            "class SneakySink:\n"
            "    backend: str = 'auto'\n"
            "    def __post_init__(self):\n"
            "        object.__setattr__(self, 'mode', 'fast')\n"
        )
    found = run_rules(Project(root), ["cache-key-completeness"])
    assert any("unkeyed plan field: SneakySink.mode" in f.message
               for f in found)


def test_new_sharded_sink_in_real_tree_is_caught(tmp_path):
    # the sharded-graph dispatch tables (planner _DFG_BACKENDS + executor
    # _execute_sharded) must not satisfy coverage for a sink they never saw
    root = _copy_query_tree(tmp_path)
    with open(root / "query" / "ast.py", "a") as fh:
        fh.write(
            "\n\n@dataclasses.dataclass(frozen=True)\n"
            "class ShardMergeSink:\n    backend: str = 'sharded-graph'\n"
        )
    found = run_rules(Project(root), ["backend-coverage"])
    assert {f.path for f in found} == {"query/planner.py", "query/execute.py"}
    assert all("ShardMergeSink" in f.message for f in found)


def test_unpatched_real_tree_is_clean(tmp_path):
    root = _copy_query_tree(tmp_path)
    assert run_rules(
        Project(root), ["backend-coverage", "cache-key-completeness"]
    ) == []


# ---------------------------------------------------------------------------
# The real tree + committed baseline (the CI gate, in-process)
# ---------------------------------------------------------------------------


def test_real_tree_has_no_new_findings():
    findings = run_rules(Project(REPO_ROOT))
    baseline = load_baseline(REPO_ROOT / "analysis_baseline.json")
    new, _known, stale = split_findings(findings, baseline)
    assert new == [], [f.format() for f in new]
    assert stale == [], f"stale baseline entries: {stale}"


def test_cli_fail_on_new_is_clean_on_real_repo(capsys):
    # the exact CI gate, end to end: the sharded tier's plan dataclasses
    # (HistogramSink.backend, the sharded dispatch tables, shard/store
    # locks) must not introduce findings over the committed baseline
    rc = analysis_main(
        ["--root", str(REPO_ROOT),
         "--baseline", str(REPO_ROOT / "analysis_baseline.json"),
         "--fail-on-new"]
    )
    capsys.readouterr()
    assert rc == 0


# ---------------------------------------------------------------------------
# Baseline round-trip + CLI
# ---------------------------------------------------------------------------


def test_baseline_roundtrip(tmp_path):
    findings = fixture_findings("unlocked_stats")
    assert findings
    path = tmp_path / "baseline.json"
    save_baseline(path, findings, justification="fixture")
    baseline = load_baseline(path)
    new, known, stale = split_findings(findings, baseline)
    assert new == [] and stale == []
    assert len(known) == len(findings)
    # a fixed finding leaves a stale entry behind (baselines only shrink)
    new, _known, stale = split_findings(findings[1:], baseline)
    assert new == [] and stale == [findings[0].identity()]


def test_finding_identity_ignores_line_numbers():
    a = Finding("r", "p.py", 10, "msg")
    b = Finding("r", "p.py", 99, "msg")
    assert a.identity() == b.identity()
    assert a.identity() != Finding("r", "p.py", 10, "other").identity()


def test_cli_exits_nonzero_on_new_findings(tmp_path, capsys):
    rc = analysis_main(
        ["--root", str(FIXTURES / "unlocked_stats"),
         "--baseline", str(tmp_path / "none.json"), "--fail-on-new"]
    )
    assert rc == 1
    assert "lock-discipline" in capsys.readouterr().out


def test_cli_baseline_gates_to_zero(tmp_path, capsys):
    baseline = tmp_path / "baseline.json"
    rc = analysis_main(
        ["--root", str(FIXTURES / "unlocked_stats"),
         "--baseline", str(baseline), "--write-baseline"]
    )
    assert rc == 0
    capsys.readouterr()  # drain the --write-baseline chatter
    rc = analysis_main(
        ["--root", str(FIXTURES / "unlocked_stats"),
         "--baseline", str(baseline), "--fail-on-new", "--json"]
    )
    assert rc == 0
    out = json.loads(capsys.readouterr().out)
    assert out["new"] == []
    assert out["baselined"]


# ---------------------------------------------------------------------------
# lockdep runtime sanitizer
# ---------------------------------------------------------------------------


@pytest.fixture()
def lockdep_on(monkeypatch):
    monkeypatch.setenv("REPRO_LOCKDEP", "1")
    lockdep.reset()
    yield
    lockdep.reset()


def test_make_lock_is_plain_lock_when_disabled(monkeypatch):
    monkeypatch.delenv("REPRO_LOCKDEP", raising=False)
    lock = lockdep.make_lock("x")
    assert not isinstance(lock, lockdep.LockdepLock)
    with lock:
        pass


def test_lockdep_detects_inverted_order(lockdep_on):
    a = lockdep.make_lock("A")
    b = lockdep.make_lock("B")
    with a:
        with b:
            pass
    with pytest.raises(lockdep.LockOrderError, match="inversion"):
        with b:
            with a:
                pass


def test_lockdep_detects_transitive_cycle(lockdep_on):
    a, b, c = (lockdep.make_lock(n) for n in "ABC")
    with a:
        with b:
            pass
    with b:
        with c:
            pass
    with pytest.raises(lockdep.LockOrderError):
        with c:
            with a:
                pass


def test_lockdep_detects_recursive_acquisition(lockdep_on):
    a = lockdep.make_lock("A")
    with pytest.raises(lockdep.LockOrderError, match="recursive"):
        with a:
            with a:
                pass


def test_lockdep_allows_same_name_family(lockdep_on):
    # per-log append locks share a name; members are never ordered
    a1 = lockdep.make_lock("append")
    a2 = lockdep.make_lock("append")
    with a1:
        with a2:
            pass
    with a2:
        with a1:
            pass


def test_lockdep_consistent_order_is_quiet(lockdep_on):
    a = lockdep.make_lock("A")
    b = lockdep.make_lock("B")
    for _ in range(3):
        with a:
            with b:
                pass
    assert ("A", "B") in lockdep.order_edges()


def test_engine_under_lockdep_runs_clean(lockdep_on):
    # the engine's real lock nestings must not trip the sanitizer
    from repro.data import ProcessSpec, generate_repository
    from repro.query import Q, QueryEngine

    engine = QueryEngine()
    repo = generate_repository(200, ProcessSpec(num_activities=7, seed=3))
    for _ in range(2):
        Q.log(repo).using(engine).dfg()
        Q.log(repo).using(engine).histogram()
    assert engine.metrics_snapshot()["engine_queries_total"] >= 4


# ---------------------------------------------------------------------------
# Pallas kernel resource checker
# ---------------------------------------------------------------------------


def test_validate_blocks_passes_for_picked_blocks():
    from repro.kernels.align_dp.ops import pick_blocks as pick_align
    from repro.kernels.dfg_count.ops import pick_blocks as pick_dfg
    from repro.kernels.segment_count.ops import pick_blocks as pick_seg

    for a in (8, 64, 512, 4096):
        pick_dfg(a)  # validates internally
        pick_seg(a)
    for v, l, s in ((5, 4, 3), (1000, 600, 400)):
        lp = max(128, -(-l // 128) * 128)
        sp = max(128, -(-s // 128) * 128)
        validate_blocks("align_dp", block_v=pick_align(v), lp=lp, s=sp)


def test_validate_blocks_rejects_vmem_overrun():
    with pytest.raises(KernelResourceError, match="VMEM"):
        validate_blocks("dfg_count", block_e=1 << 20, block_a=512)


def test_validate_blocks_rejects_misaligned_lane():
    with pytest.raises(KernelResourceError, match="multiple of 128"):
        validate_blocks("dfg_count", block_e=1536, block_a=384 + 12)


def test_validate_blocks_requires_full_env():
    with pytest.raises(KernelResourceError, match="unresolved symbol"):
        validate_blocks("align_dp", block_v=64)


def test_kernel_report_covers_all_kernels_within_limit():
    report = build_report()
    assert set(report["kernels"]) == {
        "dfg_count", "segment_count", "align_dp"
    }
    for kernel in report["kernels"].values():
        for scenario in kernel["scenarios"]:
            assert scenario["max_vmem_bytes"] <= report["vmem_limit_bytes"]
            for call in scenario["calls"]:
                assert call["errors"] == []


def test_committed_kernel_report_is_current():
    committed = json.loads((REPO_ROOT / "BENCH_analysis.json").read_text())
    assert committed == build_report()


# ---------------------------------------------------------------------------
# Regression tests for the violations this lint surfaced
# ---------------------------------------------------------------------------


def test_latency_hist_memo_single_instance_under_threads():
    # _trace_finish used to insert into _lat_hists without the engine lock;
    # racing threads could each build a Histogram and leak divergent memos
    from repro.data import ProcessSpec, generate_repository
    from repro.query import Q, QueryEngine

    engine = QueryEngine()
    repo = generate_repository(150, ProcessSpec(num_activities=5, seed=1))
    barrier = threading.Barrier(8)
    errors = []

    def worker():
        try:
            barrier.wait()
            for _ in range(3):
                Q.log(repo).using(engine).dfg()
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []
    # pre-fix, racing threads each built a Histogram and observed into their
    # own copy while only one won the memo slot — observations were lost.
    # The memo keys by (sink, backend), so sum across all of them.
    assert all(k[0] == "dfg" for k in engine._lat_hists)
    assert sum(h.count for h in engine._lat_hists.values()) == 24


def test_cache_eviction_drops_hints_for_dead_entries():
    # _drop_hints_for → _drop_hints_locked: the caller-holds-lock rename;
    # eviction must still clear the delta hints of the evicted entry
    from repro.query.cache import QueryCache

    cache = QueryCache(max_entries=2)

    class _R:
        value = 0
        names = None
        trace = None

    for i in range(3):
        cache.put((f"fp{i}", "plan"), _R(), source_hint=f"src{i}")
    assert cache.delta_candidate("src0", "plan") is None  # evicted
    assert cache.delta_candidate("src2", "plan") is not None
